//! Pluggable compute backends: the trait boundary between the training
//! coordinator and whatever actually executes the masked ViT numerics.
//!
//! The coordinator, schedulers, cluster simulation, and experiment
//! harness only ever talk to [`Backend`] — three hot entry points
//! ([`Backend::step`], [`Backend::eval`], [`Backend::score_probe`]) plus
//! a little metadata. Two implementations ship:
//!
//! * [`native`] — a pure-Rust masked mini-ViT forward/backward on
//!   [`crate::tensor::Tensor`] (default feature `native`). Zero native
//!   dependencies, zero artifacts: every scheduler/engine scenario runs
//!   anywhere `cargo build` works.
//! * `xla` — the original PJRT path (AOT-lowered HLO artifacts executed
//!   through the `xla` crate), behind the optional `xla` cargo feature.
//!
//! ## Mask semantics (shared contract)
//!
//! Both backends honor [`MaskPair`] identically, per (block, head):
//!
//! | fwd | bwd | op  | forward                     | parameters        |
//! |-----|-----|-----|-----------------------------|-------------------|
//! | 1   | 1   | p_f | head participates           | updated           |
//! | 1   | 0   | p_o | head participates           | frozen            |
//! | 0   | 0   | p_s | identity (residual carries) | frozen (no grads) |
//!
//! A skipped (p_s) subnet contributes *exactly* the residual identity:
//! masking every head of a block makes the block a no-op, bitwise.

#[cfg(feature = "native")]
pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

use std::path::Path;

use crate::runtime::ModelConfig;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;
use crate::Result;

/// Output of one training step.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    /// Mean loss over the micro-batch.
    pub loss: f32,
    /// Correct predictions in the micro-batch.
    pub n_correct: f32,
}

/// Output of one forward-only evaluation pass.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    /// Mean loss over the micro-batch.
    pub loss: f32,
    /// Correct predictions in the micro-batch.
    pub n_correct: f32,
}

/// One compute backend instance: owns the model parameters + optimizer
/// state and executes the three hot entry points the trainer needs.
pub trait Backend {
    /// Short display label (`"native"` / `"xla"`).
    fn label(&self) -> &'static str;

    /// The model configuration this backend trains.
    fn config(&self) -> &ModelConfig;

    /// Micro-batch size of the training step.
    fn micro_batch(&self) -> usize;

    /// Micro-batch size of the eval pass (differs from
    /// [`Backend::micro_batch`] only for XLA trainstep variants, whose
    /// eval program stays at the base size).
    fn eval_micro_batch(&self) -> usize {
        self.micro_batch()
    }

    /// Whether [`Backend::score_probe`] is available (XLA trainstep
    /// variants lack a probe artifact at their micro-batch size).
    fn supports_probe(&self) -> bool {
        true
    }

    /// One fused fwd + bwd + SGD-momentum step on a micro-batch under a
    /// schedule row's masks. Updates parameters in place.
    fn step(&mut self, x: &Tensor, y: &[i32], masks: &MaskPair, lr: f32) -> Result<StepOut>;

    /// Whether this backend can expose raw gradients for exchange
    /// ([`Backend::grad_step`] / [`Backend::apply_grads`]). The native
    /// backend can; the XLA path cannot (its fused trainstep artifact
    /// updates parameters internally and never materializes gradients on
    /// the host).
    fn supports_grad_exchange(&self) -> bool {
        false
    }

    /// Forward + backward **without** updating parameters: the step
    /// stats plus the dense masked gradients, one tensor per parameter
    /// in [`Backend::param_names`] order. `p_o`/`p_s` head slices are
    /// exactly zero (the [`MaskPair`] freeze contract), which is what
    /// makes the `dist` masked wire format lossless. A `step()` is
    /// bitwise `grad_step()` followed by `apply_grads()` of the result.
    fn grad_step(&self, x: &Tensor, y: &[i32], masks: &MaskPair) -> Result<(StepOut, Vec<Tensor>)> {
        let _ = (x, y, masks);
        anyhow::bail!(
            "backend {:?} does not expose gradients for exchange (native only)",
            self.label()
        )
    }

    /// Apply pre-aggregated gradients with the fused SGD-momentum rule
    /// (`m = mu*m + g; p -= lr*m` on every trainable tensor) — the
    /// second half of a [`Backend::step`], fed by a gradient reduction.
    fn apply_grads(&mut self, grads: &[Tensor], lr: f32) -> Result<()> {
        let _ = (grads, lr);
        anyhow::bail!(
            "backend {:?} does not accept external gradients (native only)",
            self.label()
        )
    }

    /// Forward-only pass: loss + correct count (all-subnets mask unless
    /// a partial fwd mask is given — the timed `p_o` program).
    fn eval(&self, x: &Tensor, y: &[i32], fwd_mask: Option<&Tensor>) -> Result<EvalOut>;

    /// Contribution-score probe: `[L, H, 4]` (fisher, grad-mag, taylor,
    /// weight-mag) for one micro-batch, without updating weights.
    fn score_probe(&self, x: &Tensor, y: &[i32]) -> Result<Tensor>;

    /// Zero the momentum buffers (fresh optimizer state at the
    /// pretrain -> fine-tune boundary).
    fn reset_momentum(&mut self) -> Result<()>;

    /// Copy of one named parameter tensor (host inspection; tests).
    fn param(&self, name: &str) -> Option<Tensor>;

    /// All parameter names, in the backend's canonical order.
    fn param_names(&self) -> Vec<String>;
}

/// Selects which model variant a provider should open.
#[derive(Clone, Copy, Debug)]
pub struct BackendSel {
    /// LoRA adapter rank (0 = full fine-tuning).
    pub lora_rank: usize,
    /// Trainstep micro-batch override (Table VI variants); `None` uses
    /// the provider's default.
    pub micro_batch: Option<usize>,
    /// Seed for backends that initialize parameters themselves (the
    /// native backend; the XLA backend loads the shipped init blob).
    pub seed: u64,
}

impl BackendSel {
    /// The full fine-tuning model at the provider's default micro-batch.
    pub fn full(seed: u64) -> BackendSel {
        BackendSel { lora_rank: 0, micro_batch: None, seed }
    }
}

/// A family of openable backends (full FT + LoRA ranks + micro-batch
/// variants) sharing one model configuration — the backend-agnostic
/// replacement for handing an `ArtifactRegistry` around.
pub trait BackendProvider {
    /// Short display label (`"native"` / `"xla"`).
    fn label(&self) -> &'static str;

    /// Model configuration of the full fine-tuning variant.
    fn model_config(&self) -> &ModelConfig;

    /// Default trainstep micro-batch size.
    fn micro_batch(&self) -> usize;

    /// Alternative micro-batch sizes this provider can open (Table VI).
    fn mb_variants(&self) -> Vec<usize>;

    /// LoRA ranks this provider can open (empty = full FT only).
    fn lora_ranks(&self) -> Vec<usize>;

    /// The rank used by default for LoRA experiments (0 = none).
    fn lora_standard_rank(&self) -> usize;

    /// Number of parameter tensors in the full variant (for `repro info`).
    fn n_params(&self) -> usize;

    /// Total f32 elements across the full variant's parameters.
    fn total_elems(&self) -> usize;

    /// Open a backend instance for the selected variant.
    fn open(&self, sel: &BackendSel) -> Result<Box<dyn Backend + '_>>;
}

/// Which backend implementation to use (CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust mini-ViT (no native dependencies, no artifacts).
    Native,
    /// PJRT / AOT-artifact path (requires the `xla` feature + artifacts).
    Xla,
}

impl BackendKind {
    /// Parse a CLI backend label.
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "native" => BackendKind::Native,
            "xla" | "pjrt" => BackendKind::Xla,
            _ => anyhow::bail!("unknown backend {s:?} (native|xla)"),
        })
    }

    /// The CLI label of this backend kind.
    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Build the provider for `kind`. `artifacts_dir` is only consulted by
/// the XLA provider; the native provider needs no files at all.
pub fn provider_for(kind: BackendKind, artifacts_dir: &Path) -> Result<Box<dyn BackendProvider>> {
    match kind {
        BackendKind::Native => native_provider(),
        BackendKind::Xla => xla_provider(artifacts_dir),
    }
}

#[cfg(feature = "native")]
fn native_provider() -> Result<Box<dyn BackendProvider>> {
    Ok(Box::new(native::NativeProvider::default()))
}

#[cfg(not(feature = "native"))]
fn native_provider() -> Result<Box<dyn BackendProvider>> {
    anyhow::bail!("built without the `native` feature; rebuild with default features")
}

#[cfg(feature = "xla")]
fn xla_provider(artifacts_dir: &Path) -> Result<Box<dyn BackendProvider>> {
    Ok(Box::new(xla::XlaProvider::open(artifacts_dir)?))
}

#[cfg(not(feature = "xla"))]
fn xla_provider(_artifacts_dir: &Path) -> Result<Box<dyn BackendProvider>> {
    anyhow::bail!(
        "this build has no XLA support; rebuild with `cargo build --features xla` \
         (needs xla_extension) or use `--backend native`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("XLA").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Native.label(), "native");
    }

    #[test]
    fn backend_sel_full_defaults() {
        let sel = BackendSel::full(7);
        assert_eq!(sel.lora_rank, 0);
        assert_eq!(sel.micro_batch, None);
        assert_eq!(sel.seed, 7);
    }
}
