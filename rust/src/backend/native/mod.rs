//! Pure-Rust native backend: a masked mini-ViT forward/backward with a
//! fused SGD-momentum update, per-head attention skip honoring the
//! [`MaskPair`] contract, optional per-head LoRA adapters, and the
//! `[L, H, 4]` contribution-score probe — no PJRT, no artifacts, no
//! native libraries.
//!
//! ## Model
//!
//! The standard pre-LN ViT the AOT artifacts lower, scaled to train fast
//! on the synthetic corpora: patch embedding -> CLS token + learned
//! position embeddings -> `depth` transformer blocks (multi-head
//! attention + GELU FFN, both with residual connections) -> final layer
//! norm over the CLS token -> linear classifier. Parameter names mirror
//! the artifact manifest convention (`a_*` embeddings, `bXX_*` blocks,
//! `z_*` head) so host-side inspection code works against either
//! backend.
//!
//! ## Mask semantics
//!
//! The forward mask multiplies each head's attention output (before the
//! output projection) and its 1/H chunk of the FFN hidden layer, so a
//! fully-masked subnet contributes *exactly zero* to its residual branch
//! — the shortcut operation is the residual identity, bitwise. The
//! output projection and second FFN matmul carry no bias for precisely
//! this reason. The backward mask freezes the per-head parameter slices
//! (QKV columns, output-projection rows, FFN chunk, LoRA adapters) of
//! `p_o` heads after autodiff; block-shared layer norms follow the
//! block's residual stream. `p_s` heads get zero gradients for free:
//! the forward multiply already zeroed every path through them.
//!
//! ## LoRA
//!
//! At rank `r > 0` each (block, head, projection in {q, k, v}) gets an
//! `A [D, r]` / `B [r, dh]` adapter pair (`B` zero-initialized, unit
//! alpha/r scaling). Base body weights freeze; adapters and the
//! classifier head train. Per-head adapters — rather than one shared
//! pair per projection — keep the backward mask exact.

use std::collections::HashMap;

use anyhow::Result;

use crate::backend::{Backend, BackendProvider, BackendSel, EvalOut, StepOut};
use crate::runtime::{ModelConfig, ParamEntry, ParamStore};
use crate::schedule::MaskPair;
use crate::tensor::linalg::{gelu, gelu_backward, layer_norm_rows_backward, softmax_rows_backward};
use crate::tensor::Tensor;
use crate::util::rng::{fnv1a, Rng};

const EPS: f32 = 1e-5;
const MOMENTUM: f32 = 0.9;

// ---------------------------------------------------------------------------
// Spec + provider
// ---------------------------------------------------------------------------

/// The native model family: one [`ModelConfig`] plus the variants
/// (micro-batch sizes, LoRA ranks) the provider can open — the
/// dependency-free analogue of an artifact set's `index.json`.
///
/// `#[non_exhaustive]`: construct via a preset ([`NativeSpec::tiny`],
/// [`NativeSpec::small`], [`NativeSpec::preset`]) or the
/// [`NativeSpec::builder`] — fields stay pub for reading and targeted
/// mutation, but the struct-literal form is reserved to this module and
/// the builder ([`crate::config`]).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct NativeSpec {
    /// Model configuration (the `lora_rank` field is per-backend).
    pub config: ModelConfig,
    /// Default trainstep micro-batch size.
    pub micro_batch: usize,
    /// Alternative micro-batch sizes advertised for Table VI (the
    /// native step accepts any batch size; these mirror the artifact
    /// set's lowered variants).
    pub mb_variants: Vec<usize>,
    /// LoRA ranks the provider advertises.
    pub lora_ranks: Vec<usize>,
    /// The rank used by default for LoRA experiments.
    pub lora_standard_rank: usize,
    /// Base seed mixed into parameter initialization.
    pub init_seed: u64,
    /// Kernel threads for the matmul row-parallel path (0 = auto, one
    /// per core capped at 8; 1 = serial). Applied to the process-global
    /// [`crate::tensor::pool`] when a backend is opened — thread count
    /// never changes numerics (writer-owned output tiles keep every
    /// accumulation order serial-identical), so this is purely a
    /// performance knob; `repro --threads N` sets it from the CLI.
    pub threads: usize,
}

impl NativeSpec {
    /// The default scaled-down ViT: 16x16 images, 4x4 patches, dim 48,
    /// 3 blocks x 4 heads (12 schedulable body subnets), 196-class head
    /// matching the synthetic datasets.
    pub fn tiny() -> NativeSpec {
        NativeSpec {
            config: ModelConfig {
                img_size: 16,
                patch: 4,
                dim: 48,
                depth: 3,
                heads: 4,
                mlp_ratio: 4,
                classes: 196,
                lora_rank: 0,
                head_dim: 12,
                tokens: 17,
            },
            micro_batch: 4,
            mb_variants: vec![2, 8],
            lora_ranks: vec![1, 2, 4, 8],
            lora_standard_rank: 4,
            init_seed: 0xD2F7,
            threads: 1,
        }
    }

    /// ViT-small-like preset: 12 blocks x 6 heads (the paper's 72 body
    /// subnets, 74 devices with embedding + classifier), dim 96. Same
    /// 16x16 synthetic inputs and 196-class head as [`NativeSpec::tiny`]
    /// so every dataset preset works unchanged; selected with
    /// `--model small`.
    pub fn small() -> NativeSpec {
        NativeSpec {
            config: ModelConfig {
                img_size: 16,
                patch: 4,
                dim: 96,
                depth: 12,
                heads: 6,
                mlp_ratio: 4,
                classes: 196,
                lora_rank: 0,
                head_dim: 16,
                tokens: 17,
            },
            micro_batch: 4,
            mb_variants: vec![2, 8],
            lora_ranks: vec![1, 2, 4, 8],
            lora_standard_rank: 4,
            init_seed: 0xD2F7,
            threads: 1,
        }
    }

    /// Builder seeded with [`NativeSpec::tiny`]; override fields one at
    /// a time (see [`crate::config::NativeSpecBuilder`]).
    pub fn builder() -> crate::config::NativeSpecBuilder {
        crate::config::NativeSpecBuilder::new()
    }

    /// Parse a `--model` preset label (`mini`/`tiny` or `small`).
    pub fn preset(name: &str) -> anyhow::Result<NativeSpec> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "mini" | "tiny" => NativeSpec::tiny(),
            "small" | "vit-small" => NativeSpec::small(),
            _ => anyhow::bail!("unknown native model preset {name:?} (mini|small)"),
        })
    }
}

impl Default for NativeSpec {
    fn default() -> Self {
        NativeSpec::tiny()
    }
}

/// Provider opening [`NativeBackend`]s for a [`NativeSpec`].
#[derive(Clone, Debug, Default)]
pub struct NativeProvider {
    spec: NativeSpec,
}

impl NativeProvider {
    /// Provider over a custom spec.
    pub fn new(spec: NativeSpec) -> NativeProvider {
        NativeProvider { spec }
    }

    /// The spec this provider opens backends for.
    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }
}

impl BackendProvider for NativeProvider {
    fn label(&self) -> &'static str {
        "native"
    }

    fn model_config(&self) -> &ModelConfig {
        &self.spec.config
    }

    fn micro_batch(&self) -> usize {
        self.spec.micro_batch
    }

    fn mb_variants(&self) -> Vec<usize> {
        self.spec.mb_variants.clone()
    }

    fn lora_ranks(&self) -> Vec<usize> {
        self.spec.lora_ranks.clone()
    }

    fn lora_standard_rank(&self) -> usize {
        self.spec.lora_standard_rank
    }

    fn n_params(&self) -> usize {
        param_table(&self.spec.config, 0).len()
    }

    fn total_elems(&self) -> usize {
        param_table(&self.spec.config, 0)
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    fn open(&self, sel: &BackendSel) -> Result<Box<dyn Backend + '_>> {
        if sel.lora_rank > 0 {
            anyhow::ensure!(
                self.spec.lora_ranks.contains(&sel.lora_rank),
                "native spec advertises LoRA ranks {:?}, not {}",
                self.spec.lora_ranks,
                sel.lora_rank
            );
        }
        let mb = sel.micro_batch.unwrap_or(self.spec.micro_batch);
        anyhow::ensure!(mb >= 1, "micro-batch must be >= 1");
        Ok(Box::new(NativeBackend::new(&self.spec, sel.lora_rank, mb, sel.seed)))
    }
}

// ---------------------------------------------------------------------------
// Parameter table + init
// ---------------------------------------------------------------------------

/// `(name, shape)` of every parameter for `cfg` at LoRA rank `rank`,
/// in sorted-name (manifest flatten) order.
fn param_table(cfg: &ModelConfig, rank: usize) -> Vec<(String, Vec<usize>)> {
    let d = cfg.dim;
    let ppc = cfg.patch * cfg.patch * 3;
    let rd = cfg.mlp_ratio * d;
    let mut t: Vec<(String, Vec<usize>)> = vec![
        ("a_cls".into(), vec![1, 1, d]),
        ("a_patch_b".into(), vec![d]),
        ("a_patch_w".into(), vec![ppc, d]),
        ("a_pos".into(), vec![cfg.tokens, d]),
        ("z_head_b".into(), vec![cfg.classes]),
        ("z_head_w".into(), vec![d, cfg.classes]),
        ("z_ln_b".into(), vec![d]),
        ("z_ln_g".into(), vec![d]),
    ];
    for l in 0..cfg.depth {
        t.push((format!("b{l:02}_b1"), vec![rd]));
        t.push((format!("b{l:02}_ln1_b"), vec![d]));
        t.push((format!("b{l:02}_ln1_g"), vec![d]));
        t.push((format!("b{l:02}_ln2_b"), vec![d]));
        t.push((format!("b{l:02}_ln2_g"), vec![d]));
        t.push((format!("b{l:02}_w1"), vec![d, rd]));
        t.push((format!("b{l:02}_w2"), vec![rd, d]));
        t.push((format!("b{l:02}_wo"), vec![d, d]));
        t.push((format!("b{l:02}_wqkv"), vec![d, 3 * d]));
        if rank > 0 {
            for p in ["q", "k", "v"] {
                t.push((format!("b{l:02}_lora_a{p}"), vec![cfg.heads, d, rank]));
                t.push((format!("b{l:02}_lora_b{p}"), vec![cfg.heads, rank, cfg.head_dim]));
            }
        }
    }
    t.sort_by(|a, b| a.0.cmp(&b.0));
    t
}

/// Initialize one named parameter: layer-norm gains 1, biases and LoRA
/// `B` matrices 0, embeddings N(0, 0.02), weight matrices
/// N(0, 1/sqrt(fan_in)). Each tensor draws from its own name-keyed RNG
/// stream, so parameters shared between model depths (embeddings, head,
/// shallower blocks) initialize identically — the property the
/// residual-identity tests lean on.
fn init_param(name: &str, shape: &[usize], cfg: &ModelConfig, base_seed: u64) -> Tensor {
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(base_seed ^ fnv1a(name));
    let normal = |rng: &mut Rng, std: f32| -> Vec<f32> {
        (0..n).map(|_| rng.next_normal() * std).collect()
    };
    let d = cfg.dim as f32;
    let data = if name.ends_with("ln1_g") || name.ends_with("ln2_g") || name == "z_ln_g" {
        vec![1.0; n]
    } else if name.ends_with("_b")
        || name.ends_with("ln1_b")
        || name.ends_with("ln2_b")
        || name.ends_with("b1")
        || name.contains("_lora_b")
    {
        vec![0.0; n]
    } else if name == "a_cls" || name == "a_pos" {
        normal(&mut rng, 0.02)
    } else if name == "a_patch_w" {
        normal(&mut rng, 1.0 / ((cfg.patch * cfg.patch * 3) as f32).sqrt())
    } else if name.ends_with("w2") {
        normal(&mut rng, 1.0 / ((cfg.mlp_ratio as f32) * d).sqrt())
    } else {
        // wqkv, wo, w1, z_head_w, lora_a*: fan-in D.
        normal(&mut rng, 1.0 / d.sqrt())
    };
    Tensor::from_vec(shape, data)
}

// ---------------------------------------------------------------------------
// Small dense helpers (row-major 2-D blocks)
// ---------------------------------------------------------------------------

fn add_bias_rows(t: &mut Tensor, bias: &Tensor) {
    let n = t.shape()[1];
    assert_eq!(bias.len(), n);
    let b = bias.data().to_vec();
    for row in t.data_mut().chunks_exact_mut(n) {
        for (x, &bv) in row.iter_mut().zip(&b) {
            *x += bv;
        }
    }
}

fn col_sums(t: &Tensor) -> Tensor {
    let n = t.shape()[1];
    let mut out = vec![0.0f32; n];
    for row in t.data().chunks_exact(n) {
        for (o, &x) in out.iter_mut().zip(row) {
            *o += x;
        }
    }
    Tensor::from_vec(&[n], out)
}

fn add_t(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = a.clone();
    out.add_assign(b);
    out
}

/// Copy the `[row_lo..row_hi, col_lo..col_hi]` block of a 2-D tensor.
fn block_slice(src: &Tensor, row_lo: usize, row_hi: usize, col_lo: usize, col_hi: usize) -> Tensor {
    let n = src.shape()[1];
    let (rows, cols) = (row_hi - row_lo, col_hi - col_lo);
    let mut out = vec![0.0f32; rows * cols];
    let s = src.data();
    for r in 0..rows {
        let srow = (row_lo + r) * n + col_lo;
        out[r * cols..(r + 1) * cols].copy_from_slice(&s[srow..srow + cols]);
    }
    Tensor::from_vec(&[rows, cols], out)
}

/// `dst[row_lo.., col_lo..] += src` for a 2-D block.
fn add_block(dst: &mut Tensor, src: &Tensor, row_lo: usize, col_lo: usize) {
    let n = dst.shape()[1];
    let (rows, cols) = (src.shape()[0], src.shape()[1]);
    let s = src.data();
    let d = dst.data_mut();
    for r in 0..rows {
        let drow = (row_lo + r) * n + col_lo;
        for c in 0..cols {
            d[drow + c] += s[r * cols + c];
        }
    }
}

/// Multiply columns `[col_lo, col_hi)` of a 2-D tensor by `f`.
fn scale_cols(t: &mut Tensor, col_lo: usize, col_hi: usize, f: f32) {
    let n = t.shape()[1];
    for row in t.data_mut().chunks_exact_mut(n) {
        for x in &mut row[col_lo..col_hi] {
            *x *= f;
        }
    }
}

/// View head `h` of a `[H, a, b]` adapter stack as an `[a, b]` tensor.
fn head_of(stack: &Tensor, h: usize) -> Tensor {
    let (a, b) = (stack.shape()[1], stack.shape()[2]);
    let lo = h * a * b;
    Tensor::from_vec(&[a, b], stack.data()[lo..lo + a * b].to_vec())
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// Per-block parameter indices (resolved once at construction).
#[derive(Clone, Debug)]
struct BlockIdx {
    ln1_g: usize,
    ln1_b: usize,
    wqkv: usize,
    wo: usize,
    ln2_g: usize,
    ln2_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    /// `[aq, ak, av]` / `[bq, bk, bv]` when LoRA is active.
    lora_a: Vec<usize>,
    lora_b: Vec<usize>,
}

/// Top-level parameter indices.
#[derive(Clone, Debug)]
struct TopIdx {
    cls: usize,
    patch_w: usize,
    patch_b: usize,
    pos: usize,
    z_ln_g: usize,
    z_ln_b: usize,
    head_w: usize,
    head_b: usize,
}

/// The pure-Rust compute backend (see the module docs).
pub struct NativeBackend {
    cfg: ModelConfig,
    mb: usize,
    names: Vec<String>,
    index: HashMap<String, usize>,
    params: Vec<Tensor>,
    momentum: Vec<Tensor>,
    trainable: Vec<bool>,
    blocks: Vec<BlockIdx>,
    top: TopIdx,
    lora_scale: f32,
}

/// Forward-pass caches for one block.
struct BlockCache {
    x_in: Tensor,
    n1: Tensor,
    ln1_mean: Tensor,
    ln1_rstd: Tensor,
    qkv: Tensor,
    /// Per (projection, head) LoRA mids `[N, r]` (index `p * H + h`).
    lora_mid: Vec<Tensor>,
    /// Per (sample, head) attention weights `[T, T]` (index `b * H + h`).
    att: Vec<Tensor>,
    merged: Tensor,
    x_mid: Tensor,
    n2: Tensor,
    ln2_mean: Tensor,
    ln2_rstd: Tensor,
    hid_pre: Tensor,
    hid_act: Tensor,
}

/// Full forward-pass caches.
struct Fwd {
    mb: usize,
    tok: Tensor,
    blocks: Vec<BlockCache>,
    cls_x: Tensor,
    zn: Tensor,
    z_mean: Tensor,
    z_rstd: Tensor,
    probs: Tensor,
}

impl NativeBackend {
    /// Build a backend: deterministic parameter init from
    /// `(spec.init_seed, seed)`, LoRA adapters at `lora_rank` (0 = full
    /// fine-tuning), zero momentum.
    pub fn new(
        spec: &NativeSpec,
        lora_rank: usize,
        micro_batch: usize,
        seed: u64,
    ) -> NativeBackend {
        // The kernel pool is process-global (tensor ops carry no backend
        // handle); the knob is numerics-neutral, so "last opened backend
        // wins" is safe. See `tensor::pool`.
        crate::tensor::pool::configure(spec.threads);
        let mut cfg = spec.config.clone();
        cfg.lora_rank = lora_rank;
        assert_eq!(cfg.dim, cfg.heads * cfg.head_dim, "dim must equal heads * head_dim");
        assert_eq!(cfg.tokens, (cfg.img_size / cfg.patch).pow(2) + 1, "tokens mismatch");
        let base_seed = spec.init_seed ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let table = param_table(&cfg, lora_rank);
        let names: Vec<String> = table.iter().map(|(n, _)| n.clone()).collect();
        let index: HashMap<String, usize> =
            names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        let params: Vec<Tensor> = table
            .iter()
            .map(|(n, s)| init_param(n, s, &cfg, base_seed))
            .collect();
        let momentum: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let trainable: Vec<bool> = names
            .iter()
            .map(|n| lora_rank == 0 || n.contains("_lora_") || n.starts_with("z_head"))
            .collect();
        let at = |n: &str| -> usize { index[n] };
        let blocks = (0..cfg.depth)
            .map(|l| BlockIdx {
                ln1_g: at(&format!("b{l:02}_ln1_g")),
                ln1_b: at(&format!("b{l:02}_ln1_b")),
                wqkv: at(&format!("b{l:02}_wqkv")),
                wo: at(&format!("b{l:02}_wo")),
                ln2_g: at(&format!("b{l:02}_ln2_g")),
                ln2_b: at(&format!("b{l:02}_ln2_b")),
                w1: at(&format!("b{l:02}_w1")),
                b1: at(&format!("b{l:02}_b1")),
                w2: at(&format!("b{l:02}_w2")),
                lora_a: if lora_rank > 0 {
                    ["q", "k", "v"]
                        .iter()
                        .map(|p| at(&format!("b{l:02}_lora_a{p}")))
                        .collect()
                } else {
                    Vec::new()
                },
                lora_b: if lora_rank > 0 {
                    ["q", "k", "v"]
                        .iter()
                        .map(|p| at(&format!("b{l:02}_lora_b{p}")))
                        .collect()
                } else {
                    Vec::new()
                },
            })
            .collect();
        let top = TopIdx {
            cls: at("a_cls"),
            patch_w: at("a_patch_w"),
            patch_b: at("a_patch_b"),
            pos: at("a_pos"),
            z_ln_g: at("z_ln_g"),
            z_ln_b: at("z_ln_b"),
            head_w: at("z_head_w"),
            head_b: at("z_head_b"),
        };
        NativeBackend {
            cfg,
            mb: micro_batch,
            names,
            index,
            params,
            momentum,
            trainable,
            blocks,
            top,
            // alpha = r -> unit scale: rank-independent gradient size.
            lora_scale: 1.0,
        }
    }

    fn p(&self, i: usize) -> &Tensor {
        &self.params[i]
    }

    // ---- forward ----------------------------------------------------------

    /// Extract non-overlapping patches: `[mb, img, img, 3]` ->
    /// `[mb * P2, patch*patch*3]` row-major patch vectors.
    fn patches(&self, x: &Tensor) -> Tensor {
        let c = &self.cfg;
        let np = c.img_size / c.patch;
        let p2 = np * np;
        let ppc = c.patch * c.patch * 3;
        let mb = x.shape()[0];
        assert_eq!(x.shape(), &[mb, c.img_size, c.img_size, 3], "input shape");
        let xd = x.data();
        let mut tok = vec![0.0f32; mb * p2 * ppc];
        for b in 0..mb {
            for pi in 0..np {
                for pj in 0..np {
                    let mut o = (b * p2 + pi * np + pj) * ppc;
                    for r in 0..c.patch {
                        for cc in 0..c.patch {
                            let src =
                                ((b * c.img_size + pi * c.patch + r) * c.img_size
                                    + pj * c.patch
                                    + cc)
                                    * 3;
                            tok[o..o + 3].copy_from_slice(&xd[src..src + 3]);
                            o += 3;
                        }
                    }
                }
            }
        }
        Tensor::from_vec(&[mb * p2, ppc], tok)
    }

    fn forward(&self, x: &Tensor, fwd_mask: &Tensor) -> Fwd {
        let c = &self.cfg;
        let (d, hn, dh, t) = (c.dim, c.heads, c.head_dim, c.tokens);
        let p2 = t - 1;
        let rd = c.mlp_ratio * d;
        let chunk = rd / hn;
        let mb = x.shape()[0];
        let n = mb * t;
        assert_eq!(fwd_mask.shape(), &[c.depth, hn], "fwd mask shape");

        // Embeddings: patches -> linear -> CLS prepend -> position add.
        let tok = self.patches(x);
        let mut emb = tok.matmul(self.p(self.top.patch_w));
        add_bias_rows(&mut emb, self.p(self.top.patch_b));
        let cls = self.p(self.top.cls).data();
        let pos = self.p(self.top.pos).data();
        let mut h0 = vec![0.0f32; n * d];
        for b in 0..mb {
            let row0 = (b * t) * d;
            for j in 0..d {
                h0[row0 + j] = cls[j] + pos[j];
            }
            for i in 0..p2 {
                let src = (b * p2 + i) * d;
                let dst = (b * t + 1 + i) * d;
                for j in 0..d {
                    h0[dst + j] = emb.data()[src + j] + pos[(1 + i) * d + j];
                }
            }
        }
        let mut hcur = Tensor::from_vec(&[n, d], h0);

        let mut blocks = Vec::with_capacity(c.depth);
        let scale = 1.0 / (dh as f32).sqrt();
        for (l, bi) in self.blocks.iter().enumerate() {
            let x_in = hcur;
            let (n1, ln1_mean, ln1_rstd) =
                x_in.layer_norm_rows(self.p(bi.ln1_g), self.p(bi.ln1_b), EPS);
            let mut qkv = n1.matmul(self.p(bi.wqkv));
            let mut lora_mid = Vec::new();
            if c.lora_rank > 0 {
                for p in 0..3 {
                    for hh in 0..hn {
                        let a = head_of(self.p(bi.lora_a[p]), hh);
                        let bm = head_of(self.p(bi.lora_b[p]), hh);
                        let mid = n1.matmul(&a);
                        let mut delta = mid.matmul(&bm);
                        delta.scale(self.lora_scale);
                        add_block(&mut qkv, &delta, 0, p * d + hh * dh);
                        lora_mid.push(mid);
                    }
                }
            }
            // Per-(sample, head) attention; masked head outputs merge
            // into [N, D] before the (bias-free) output projection.
            let mut att = Vec::with_capacity(mb * hn);
            let mut merged = Tensor::zeros(&[n, d]);
            for b in 0..mb {
                let r0 = b * t;
                for hh in 0..hn {
                    let q = block_slice(&qkv, r0, r0 + t, hh * dh, (hh + 1) * dh);
                    let k = block_slice(&qkv, r0, r0 + t, d + hh * dh, d + (hh + 1) * dh);
                    let v =
                        block_slice(&qkv, r0, r0 + t, 2 * d + hh * dh, 2 * d + (hh + 1) * dh);
                    let mut sc = q.matmul_nt(&k);
                    sc.scale(scale);
                    let a = sc.softmax_rows();
                    let mut out = a.matmul(&v);
                    out.scale(fwd_mask.at(&[l, hh]));
                    add_block(&mut merged, &out, r0, hh * dh);
                    att.push(a);
                }
            }
            let proj = merged.matmul(self.p(bi.wo));
            let x_mid = add_t(&x_in, &proj);
            let (n2, ln2_mean, ln2_rstd) =
                x_mid.layer_norm_rows(self.p(bi.ln2_g), self.p(bi.ln2_b), EPS);
            let mut hid_pre = n2.matmul(self.p(bi.w1));
            add_bias_rows(&mut hid_pre, self.p(bi.b1));
            let mut hid_act = gelu(&hid_pre);
            for hh in 0..hn {
                scale_cols(&mut hid_act, hh * chunk, (hh + 1) * chunk, fwd_mask.at(&[l, hh]));
            }
            let ffn = hid_act.matmul(self.p(bi.w2));
            hcur = add_t(&x_mid, &ffn);
            blocks.push(BlockCache {
                x_in,
                n1,
                ln1_mean,
                ln1_rstd,
                qkv,
                lora_mid,
                att,
                merged,
                x_mid,
                n2,
                ln2_mean,
                ln2_rstd,
                hid_pre,
                hid_act,
            });
        }

        // CLS token -> final LN -> classifier -> softmax.
        let mut cls_x = Tensor::zeros(&[mb, d]);
        for b in 0..mb {
            let row = block_slice(&hcur, b * t, b * t + 1, 0, d);
            add_block(&mut cls_x, &row, b, 0);
        }
        let (zn, z_mean, z_rstd) =
            cls_x.layer_norm_rows(self.p(self.top.z_ln_g), self.p(self.top.z_ln_b), EPS);
        let mut logits = zn.matmul(self.p(self.top.head_w));
        add_bias_rows(&mut logits, self.p(self.top.head_b));
        let probs = logits.softmax_rows();
        Fwd { mb, tok, blocks, cls_x, zn, z_mean, z_rstd, probs }
    }

    /// Cross-entropy loss + correct count + `d_logits` from cached probs.
    fn loss_grad(&self, fwd: &Fwd, y: &[i32]) -> (f32, f32, Tensor) {
        let c = self.cfg.classes;
        let mb = fwd.mb;
        assert_eq!(y.len(), mb, "label count");
        let probs = fwd.probs.data();
        let mut loss = 0.0f64;
        let mut n_correct = 0.0f32;
        let mut d = fwd.probs.clone();
        let dd = d.data_mut();
        for b in 0..mb {
            let cls = y[b] as usize;
            assert!(cls < c, "label {cls} out of range for {c} classes");
            let row = &probs[b * c..(b + 1) * c];
            loss += -(row[cls].max(1e-12) as f64).ln();
            let mut best = 0;
            for (j, &p) in row.iter().enumerate() {
                if p > row[best] {
                    best = j;
                }
            }
            if best == cls {
                n_correct += 1.0;
            }
            dd[b * c + cls] -= 1.0;
        }
        d.scale(1.0 / mb as f32);
        ((loss / mb as f64) as f32, n_correct, d)
    }

    /// Backward pass: gradients for every parameter (aligned with
    /// `self.params`). `p_s` heads receive zero gradients automatically
    /// because the forward multiply zeroed every path through them.
    fn backward(&self, fwd: &Fwd, fwd_mask: &Tensor, d_logits: &Tensor) -> Vec<Tensor> {
        let c = &self.cfg;
        let (d, hn, dh, t) = (c.dim, c.heads, c.head_dim, c.tokens);
        let p2 = t - 1;
        let rd = c.mlp_ratio * d;
        let chunk = rd / hn;
        let mb = fwd.mb;
        let mut g: Vec<Tensor> = self.params.iter().map(|p| Tensor::zeros(p.shape())).collect();

        // Classifier + final LN.
        g[self.top.head_w] = fwd.zn.matmul_tn(d_logits);
        g[self.top.head_b] = col_sums(d_logits);
        let d_zn = d_logits.matmul_nt(self.p(self.top.head_w));
        let (d_cls_x, dzg, dzb) = layer_norm_rows_backward(
            &fwd.cls_x,
            self.p(self.top.z_ln_g),
            &fwd.z_mean,
            &fwd.z_rstd,
            &d_zn,
        );
        g[self.top.z_ln_g] = dzg;
        g[self.top.z_ln_b] = dzb;

        // Scatter CLS-row gradients into the token stream.
        let mut d_h = Tensor::zeros(&[mb * t, d]);
        for b in 0..mb {
            let row = block_slice(&d_cls_x, b, b + 1, 0, d);
            add_block(&mut d_h, &row, b * t, 0);
        }

        let scale = 1.0 / (dh as f32).sqrt();
        for (l, (bi, cache)) in self.blocks.iter().zip(&fwd.blocks).enumerate().rev() {
            let d_x_out = d_h;
            // FFN branch.
            g[bi.w2] = cache.hid_act.matmul_tn(&d_x_out);
            let mut d_hid_act = d_x_out.matmul_nt(self.p(bi.w2));
            for hh in 0..hn {
                scale_cols(&mut d_hid_act, hh * chunk, (hh + 1) * chunk, fwd_mask.at(&[l, hh]));
            }
            let d_hid_pre = gelu_backward(&cache.hid_pre, &d_hid_act);
            g[bi.w1] = cache.n2.matmul_tn(&d_hid_pre);
            g[bi.b1] = col_sums(&d_hid_pre);
            let d_n2 = d_hid_pre.matmul_nt(self.p(bi.w1));
            let (d_xmid_ln, dg2, db2) = layer_norm_rows_backward(
                &cache.x_mid,
                self.p(bi.ln2_g),
                &cache.ln2_mean,
                &cache.ln2_rstd,
                &d_n2,
            );
            g[bi.ln2_g] = dg2;
            g[bi.ln2_b] = db2;
            let d_x_mid = add_t(&d_x_out, &d_xmid_ln);

            // Attention branch.
            g[bi.wo] = cache.merged.matmul_tn(&d_x_mid);
            let d_merged = d_x_mid.matmul_nt(self.p(bi.wo));
            let mut d_qkv = Tensor::zeros(&[mb * t, 3 * d]);
            for b in 0..mb {
                let r0 = b * t;
                for hh in 0..hn {
                    let att = &cache.att[b * hn + hh];
                    let mut d_out = block_slice(&d_merged, r0, r0 + t, hh * dh, (hh + 1) * dh);
                    d_out.scale(fwd_mask.at(&[l, hh]));
                    let q = block_slice(&cache.qkv, r0, r0 + t, hh * dh, (hh + 1) * dh);
                    let k = block_slice(
                        &cache.qkv, r0, r0 + t, d + hh * dh, d + (hh + 1) * dh,
                    );
                    let v = block_slice(
                        &cache.qkv, r0, r0 + t, 2 * d + hh * dh, 2 * d + (hh + 1) * dh,
                    );
                    let d_att = d_out.matmul_nt(&v);
                    let d_v = att.matmul_tn(&d_out);
                    let mut d_sc = softmax_rows_backward(att, &d_att);
                    d_sc.scale(scale);
                    let d_q = d_sc.matmul(&k);
                    let d_k = d_sc.matmul_tn(&q);
                    add_block(&mut d_qkv, &d_q, r0, hh * dh);
                    add_block(&mut d_qkv, &d_k, r0, d + hh * dh);
                    add_block(&mut d_qkv, &d_v, r0, 2 * d + hh * dh);
                }
            }
            // LoRA branch (delta was added into qkv, so d_qkv slices are
            // exactly the adapter outputs' gradients).
            let mut d_n1 = d_qkv.matmul_nt(self.p(bi.wqkv));
            if c.lora_rank > 0 {
                let r = c.lora_rank;
                for p in 0..3 {
                    for hh in 0..hn {
                        let d_slice = block_slice(
                            &d_qkv, 0, mb * t, p * d + hh * dh, p * d + (hh + 1) * dh,
                        );
                        let mid = &cache.lora_mid[p * hn + hh];
                        let a = head_of(self.p(bi.lora_a[p]), hh);
                        let bm = head_of(self.p(bi.lora_b[p]), hh);
                        let mut d_b = mid.matmul_tn(&d_slice);
                        d_b.scale(self.lora_scale);
                        let mut d_mid = d_slice.matmul_nt(&bm);
                        d_mid.scale(self.lora_scale);
                        let d_a = cache.n1.matmul_tn(&d_mid);
                        // Accumulate into the [H, ., .] stacks.
                        let off_a = hh * d * r;
                        let ga = g[bi.lora_a[p]].data_mut();
                        for (i, &x) in d_a.data().iter().enumerate() {
                            ga[off_a + i] += x;
                        }
                        let off_b = hh * r * dh;
                        let gb = g[bi.lora_b[p]].data_mut();
                        for (i, &x) in d_b.data().iter().enumerate() {
                            gb[off_b + i] += x;
                        }
                        d_n1.add_assign(&d_mid.matmul_nt(&a));
                    }
                }
            }
            g[bi.wqkv] = cache.n1.matmul_tn(&d_qkv);
            let (d_xin_ln, dg1, db1) = layer_norm_rows_backward(
                &cache.x_in,
                self.p(bi.ln1_g),
                &cache.ln1_mean,
                &cache.ln1_rstd,
                &d_n1,
            );
            g[bi.ln1_g] = dg1;
            g[bi.ln1_b] = db1;
            d_h = add_t(&d_x_mid, &d_xin_ln);
        }

        // Embeddings.
        let d_h0 = d_h;
        {
            let gp = g[self.top.pos].data_mut();
            let dd = d_h0.data();
            for b in 0..mb {
                for tt in 0..t {
                    let src = (b * t + tt) * d;
                    for j in 0..d {
                        gp[tt * d + j] += dd[src + j];
                    }
                }
            }
        }
        {
            let gc = g[self.top.cls].data_mut();
            let dd = d_h0.data();
            for b in 0..mb {
                let src = (b * t) * d;
                for j in 0..d {
                    gc[j] += dd[src + j];
                }
            }
        }
        let mut d_emb = Tensor::zeros(&[mb * p2, d]);
        for b in 0..mb {
            let rows = block_slice(&d_h0, b * t + 1, (b + 1) * t, 0, d);
            add_block(&mut d_emb, &rows, b * p2, 0);
        }
        g[self.top.patch_w] = fwd.tok.matmul_tn(&d_emb);
        g[self.top.patch_b] = col_sums(&d_emb);
        g
    }

    /// Visit every parameter element owned by subnet (block `l`, head
    /// `h`): QKV columns, output-projection rows, the head's FFN chunk,
    /// and its LoRA adapters. Shared by the backward-mask freeze and the
    /// score probe.
    fn for_head_elems(&self, l: usize, h: usize, f: &mut dyn FnMut(usize, usize)) {
        let c = &self.cfg;
        let (d, dh) = (c.dim, c.head_dim);
        let rd = c.mlp_ratio * d;
        let chunk = rd / c.heads;
        let bi = &self.blocks[l];
        for r in 0..d {
            for p in 0..3 {
                for cc in h * dh..(h + 1) * dh {
                    f(bi.wqkv, r * 3 * d + p * d + cc);
                }
            }
        }
        for r in h * dh..(h + 1) * dh {
            for cc in 0..d {
                f(bi.wo, r * d + cc);
            }
        }
        for r in 0..d {
            for cc in h * chunk..(h + 1) * chunk {
                f(bi.w1, r * rd + cc);
            }
        }
        for cc in h * chunk..(h + 1) * chunk {
            f(bi.b1, cc);
        }
        for r in h * chunk..(h + 1) * chunk {
            for cc in 0..d {
                f(bi.w2, r * d + cc);
            }
        }
        if c.lora_rank > 0 {
            let r = c.lora_rank;
            for p in 0..3 {
                for i in h * d * r..(h + 1) * d * r {
                    f(bi.lora_a[p], i);
                }
                for i in h * r * dh..(h + 1) * r * dh {
                    f(bi.lora_b[p], i);
                }
            }
        }
    }

    /// Zero the per-head parameter gradients of every head whose
    /// backward mask is 0 — the `p_o` freeze. Block-shared layer norms
    /// are left to the residual stream (matching the artifact path's
    /// observable contract: only per-head slices are guaranteed frozen).
    fn freeze(&self, grads: &mut [Tensor], bwd_mask: &Tensor) {
        for l in 0..self.cfg.depth {
            for h in 0..self.cfg.heads {
                if bwd_mask.at(&[l, h]) < 0.5 {
                    self.for_head_elems(l, h, &mut |pi, ei| {
                        grads[pi].data_mut()[ei] = 0.0;
                    });
                }
            }
        }
    }

    /// SGD-momentum update matching the artifact trainstep's contract:
    /// `m = mu * m + g; p -= lr * m` on every trainable tensor.
    fn update(&mut self, grads: &[Tensor], lr: f32) {
        for i in 0..self.params.len() {
            if !self.trainable[i] {
                continue;
            }
            let m = self.momentum[i].data_mut();
            let p = self.params[i].data_mut();
            for ((mv, pv), &gv) in m.iter_mut().zip(p.iter_mut()).zip(grads[i].data()) {
                *mv = MOMENTUM * *mv + gv;
                *pv -= lr * *mv;
            }
        }
    }

    /// Gradients for one micro-batch under `masks` without updating any
    /// parameter — `(name, grad)` pairs in canonical order. Diagnostic
    /// API backing the finite-difference tests and the score probe.
    pub fn param_grads(&self, x: &Tensor, y: &[i32], masks: &MaskPair) -> Vec<(String, Tensor)> {
        let fwd = self.forward(x, &masks.fwd);
        let (_, _, d_logits) = self.loss_grad(&fwd, y);
        let mut grads = self.backward(&fwd, &masks.fwd, &d_logits);
        self.freeze(&mut grads, &masks.bwd);
        self.names.iter().cloned().zip(grads).collect()
    }

    /// Add `delta` to one element of a named parameter (finite-difference
    /// test hook).
    pub fn nudge_param(&mut self, name: &str, elem: usize, delta: f32) {
        let i = self.index[name];
        self.params[i].data_mut()[elem] += delta;
    }

    // ---- gradient-exchange surface (the `dist` runtime builds on these)

    /// Number of parameter tensors (canonical sorted-name order).
    pub fn n_param_tensors(&self) -> usize {
        self.params.len()
    }

    /// Element count of parameter tensor `i` (canonical order).
    pub fn param_elems(&self, i: usize) -> usize {
        self.params[i].len()
    }

    /// Per-tensor trainable flags, aligned with the canonical order
    /// (false = frozen base weight under LoRA).
    pub fn trainable_flags(&self) -> &[bool] {
        &self.trainable
    }

    /// Zero tensors shaped like the parameter set — gradient
    /// accumulators for a reduction.
    pub fn zeros_like_params(&self) -> Vec<Tensor> {
        self.params.iter().map(|p| Tensor::zeros(p.shape())).collect()
    }

    /// Visit every `(param index, element index)` owned by subnet
    /// (block `l`, head `h`) — the public face of the per-head slice map
    /// the backward-mask freeze uses. The `dist` gradient codec derives
    /// its wire layout from exactly this visitation, which is what makes
    /// the masked wire format lossless.
    pub fn visit_head_elems(&self, l: usize, h: usize, f: &mut dyn FnMut(usize, usize)) {
        self.for_head_elems(l, h, f);
    }

    /// SGD-momentum update that also captures the applied per-parameter
    /// deltas (`lr * m`, dense) — the parameter-server downlink payload.
    /// Non-trainable entries are empty tensors. Bitwise identical to
    /// [`Backend::apply_grads`] on the local parameters: the delta is
    /// the very `lr * m` product the fused update subtracts.
    pub fn update_capture(&mut self, grads: &[Tensor], lr: f32) -> Vec<Tensor> {
        assert_eq!(grads.len(), self.params.len(), "grad tensor count");
        let mut deltas = Vec::with_capacity(self.params.len());
        for i in 0..self.params.len() {
            if !self.trainable[i] {
                deltas.push(Tensor::zeros(&[0]));
                continue;
            }
            let m = self.momentum[i].data_mut();
            let p = self.params[i].data_mut();
            assert_eq!(grads[i].len(), p.len(), "grad size for {}", self.names[i]);
            let mut d = vec![0.0f32; p.len()];
            for (j, ((mv, pv), &gv)) in
                m.iter_mut().zip(p.iter_mut()).zip(grads[i].data()).enumerate()
            {
                *mv = MOMENTUM * *mv + gv;
                let dv = lr * *mv;
                *pv -= dv;
                d[j] = dv;
            }
            let n = d.len();
            deltas.push(Tensor::from_vec(&[n], d));
        }
        deltas
    }

    /// Install parameter deltas (`p -= delta`) on every trainable tensor
    /// — the parameter-server worker side of [`NativeBackend::update_capture`].
    /// The local momentum buffers are untouched (the server owns the
    /// optimizer state in that topology).
    pub fn apply_deltas(&mut self, deltas: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            deltas.len() == self.params.len(),
            "delta count {} != {} parameters",
            deltas.len(),
            self.params.len()
        );
        for i in 0..self.params.len() {
            if !self.trainable[i] {
                continue;
            }
            let p = self.params[i].data_mut();
            let d = deltas[i].data();
            anyhow::ensure!(
                d.len() == p.len(),
                "delta size mismatch for {}",
                self.names[i]
            );
            for (pv, &dv) in p.iter_mut().zip(d) {
                *pv -= dv;
            }
        }
        Ok(())
    }

    // ---- ParamStore interchange (numeric parity harness) -------------------

    /// Export the parameters as a [`ParamStore`] in canonical
    /// (sorted-name, manifest flatten) order — the interchange blob the
    /// XLA path loads as `params_init.bin`, so both backends can start
    /// from bitwise-identical initializations.
    pub fn export_params(&self) -> ParamStore {
        let mut entries = Vec::with_capacity(self.params.len());
        let mut flat = Vec::new();
        let mut offset = 0;
        for (name, p) in self.names.iter().zip(&self.params) {
            entries.push(ParamEntry {
                name: name.clone(),
                shape: p.shape().to_vec(),
                size: p.len(),
                offset,
            });
            flat.extend_from_slice(p.data());
            offset += p.len();
        }
        ParamStore::from_parts(entries, flat).expect("canonical export layout")
    }

    /// Overwrite the parameters from a [`ParamStore`], matched by name
    /// (every parameter must be present with its exact element count).
    pub fn import_params(&mut self, store: &ParamStore) -> Result<()> {
        for (i, name) in self.names.iter().enumerate() {
            let s = store
                .slice(name)
                .ok_or_else(|| anyhow::anyhow!("param store is missing {name:?}"))?;
            anyhow::ensure!(
                s.len() == self.params[i].len(),
                "size mismatch for {name}: store {} vs model {}",
                s.len(),
                self.params[i].len()
            );
            self.params[i].data_mut().copy_from_slice(s);
        }
        Ok(())
    }

    /// Export only the *trainable* optimizer state — per-slot parameter
    /// and momentum tensors in canonical order, with zero-length
    /// placeholders on frozen slots. In LoRA mode this is the per-head
    /// adapters plus the classifier head: the few-KiB payload the
    /// multi-tenant service hot-swaps between jobs (the shared frozen
    /// base never leaves the replica). The shapes match exactly what
    /// `dist::GradCodec::encode_dense_append` serializes and
    /// `decode_dense` returns, so the serve wire path reuses the
    /// gradient codec unchanged.
    pub fn export_trainable(&self) -> (Vec<Tensor>, Vec<Tensor>) {
        let pack = |src: &[Tensor]| -> Vec<Tensor> {
            src.iter()
                .zip(&self.trainable)
                .map(|(t, &tr)| if tr { t.clone() } else { Tensor::zeros(&[0]) })
                .collect()
        };
        (pack(&self.params), pack(&self.momentum))
    }

    /// Install trainable state exported by [`Self::export_trainable`]
    /// (or decoded by `GradCodec::decode_dense`) on a backend built
    /// from the same spec at the same LoRA rank. Frozen slots are left
    /// untouched — the resident base parameters — and their placeholder
    /// entries are ignored.
    pub fn import_trainable(&mut self, params: &[Tensor], momentum: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.params.len() && momentum.len() == self.momentum.len(),
            "trainable state has {}/{} slots, model has {}",
            params.len(),
            momentum.len(),
            self.params.len()
        );
        for i in 0..self.params.len() {
            if !self.trainable[i] {
                continue;
            }
            anyhow::ensure!(
                params[i].len() == self.params[i].len()
                    && momentum[i].len() == self.momentum[i].len(),
                "trainable slot {} ({}) has {} elements, model needs {}",
                i,
                self.names[i],
                params[i].len(),
                self.params[i].len()
            );
            self.params[i].data_mut().copy_from_slice(params[i].data());
            self.momentum[i].data_mut().copy_from_slice(momentum[i].data());
        }
        Ok(())
    }

    /// Export the full optimizer state as two flat vectors — parameters
    /// and momentum, concatenated in canonical (names-vector) order.
    /// This is the payload of the dist control plane's `State` frame: a
    /// rejoining or resuming worker installs it to become a bitwise
    /// replica of the aggregator mid-run.
    pub fn export_state_flat(&self) -> (Vec<f32>, Vec<f32>) {
        let total: usize = self.params.iter().map(|p| p.len()).sum();
        let mut params = Vec::with_capacity(total);
        let mut momentum = Vec::with_capacity(total);
        for p in &self.params {
            params.extend_from_slice(p.data());
        }
        for m in &self.momentum {
            momentum.extend_from_slice(m.data());
        }
        (params, momentum)
    }

    /// Install optimizer state exported by [`Self::export_state_flat`]
    /// on a replica built from the same spec (positional copy; the
    /// canonical tensor order is identical by construction).
    pub fn import_state_flat(&mut self, params: &[f32], momentum: &[f32]) -> Result<()> {
        let total: usize = self.params.iter().map(|p| p.len()).sum();
        anyhow::ensure!(
            params.len() == total,
            "state params have {} elements, model needs {total}",
            params.len()
        );
        let mtotal: usize = self.momentum.iter().map(|m| m.len()).sum();
        anyhow::ensure!(
            momentum.len() == mtotal,
            "state momentum has {} elements, model needs {mtotal}",
            momentum.len()
        );
        let mut off = 0;
        for p in &mut self.params {
            let n = p.len();
            p.data_mut().copy_from_slice(&params[off..off + n]);
            off += n;
        }
        let mut off = 0;
        for m in &mut self.momentum {
            let n = m.len();
            m.data_mut().copy_from_slice(&momentum[off..off + n]);
            off += n;
        }
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn label(&self) -> &'static str {
        "native"
    }

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn micro_batch(&self) -> usize {
        self.mb
    }

    fn step(&mut self, x: &Tensor, y: &[i32], masks: &MaskPair, lr: f32) -> Result<StepOut> {
        // Exactly grad_step + apply — the decomposition the dist runtime
        // distributes, so serial and distributed execution share bits.
        let (out, grads) = Backend::grad_step(self, x, y, masks)?;
        self.update(&grads, lr);
        Ok(out)
    }

    fn supports_grad_exchange(&self) -> bool {
        true
    }

    fn grad_step(&self, x: &Tensor, y: &[i32], masks: &MaskPair) -> Result<(StepOut, Vec<Tensor>)> {
        let fwd = self.forward(x, &masks.fwd);
        let (loss, n_correct, d_logits) = self.loss_grad(&fwd, y);
        let mut grads = self.backward(&fwd, &masks.fwd, &d_logits);
        self.freeze(&mut grads, &masks.bwd);
        Ok((StepOut { loss, n_correct }, grads))
    }

    fn apply_grads(&mut self, grads: &[Tensor], lr: f32) -> Result<()> {
        anyhow::ensure!(
            grads.len() == self.params.len(),
            "grad count {} != {} parameters",
            grads.len(),
            self.params.len()
        );
        // Per-tensor sizes too: update()'s zip would otherwise silently
        // truncate a mis-sized gradient to a partial parameter update.
        for (i, g) in grads.iter().enumerate() {
            anyhow::ensure!(
                g.len() == self.params[i].len(),
                "grad size mismatch for {}: {} vs {}",
                self.names[i],
                g.len(),
                self.params[i].len()
            );
        }
        self.update(grads, lr);
        Ok(())
    }

    fn eval(&self, x: &Tensor, y: &[i32], fwd_mask: Option<&Tensor>) -> Result<EvalOut> {
        let ones = Tensor::full(&[self.cfg.depth, self.cfg.heads], 1.0);
        let fwd = self.forward(x, fwd_mask.unwrap_or(&ones));
        let (loss, n_correct, _) = self.loss_grad(&fwd, y);
        Ok(EvalOut { loss, n_correct })
    }

    fn score_probe(&self, x: &Tensor, y: &[i32]) -> Result<Tensor> {
        let masks = MaskPair::ones(self.cfg.depth, self.cfg.heads);
        let fwd = self.forward(x, &masks.fwd);
        let (_, _, d_logits) = self.loss_grad(&fwd, y);
        let grads = self.backward(&fwd, &masks.fwd, &d_logits);
        let mut out = Tensor::zeros(&[self.cfg.depth, self.cfg.heads, 4]);
        for l in 0..self.cfg.depth {
            for h in 0..self.cfg.heads {
                let mut acc = [0.0f64; 4];
                self.for_head_elems(l, h, &mut |pi, ei| {
                    let w = self.params[pi].data()[ei] as f64;
                    let g = grads[pi].data()[ei] as f64;
                    acc[0] += g * g; // fisher
                    acc[1] += g.abs(); // gradient magnitude
                    acc[2] += (w * g).abs(); // taylor importance
                    acc[3] += w.abs(); // weight magnitude
                });
                for (m, &v) in acc.iter().enumerate() {
                    out.set(&[l, h, m], v as f32);
                }
            }
        }
        Ok(out)
    }

    fn reset_momentum(&mut self) -> Result<()> {
        for m in &mut self.momentum {
            for v in m.data_mut() {
                *v = 0.0;
            }
        }
        Ok(())
    }

    fn param(&self, name: &str) -> Option<Tensor> {
        self.index.get(name).map(|&i| self.params[i].clone())
    }

    fn param_names(&self) -> Vec<String> {
        self.names.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetSpec, SyntheticKind};

    /// A very small config so unit tests stay fast.
    pub(crate) fn small_spec() -> NativeSpec {
        NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![4],
            lora_ranks: vec![2, 4],
            lora_standard_rank: 2,
            init_seed: 0xBEEF,
            threads: 1,
        }
    }

    fn sample(spec: &NativeSpec, mb: usize, seed: u64) -> (Tensor, Vec<i32>) {
        let d = DatasetSpec::preset(SyntheticKind::Cifar10Like, spec.config.img_size, mb, seed)
            .generate("train");
        d.gather(&(0..mb).collect::<Vec<_>>())
    }

    #[test]
    fn provider_metadata_and_shapes() {
        let p = NativeProvider::new(small_spec());
        assert_eq!(p.label(), "native");
        assert_eq!(p.micro_batch(), 2);
        assert_eq!(p.lora_standard_rank(), 2);
        assert!(p.n_params() > 0);
        let be = p.open(&BackendSel::full(1)).unwrap();
        assert_eq!(be.param("b00_wqkv").unwrap().shape(), &[16, 48]);
        assert_eq!(be.param("a_pos").unwrap().shape(), &[5, 16]);
        assert_eq!(be.param("z_head_w").unwrap().shape(), &[16, 10]);
        assert!(be.param("b00_lora_aq").is_none(), "no adapters at rank 0");
        assert_eq!(
            p.total_elems(),
            be.param_names()
                .iter()
                .map(|n| be.param(n).unwrap().len())
                .sum::<usize>()
        );
    }

    #[test]
    fn lora_backend_advertises_adapters() {
        let p = NativeProvider::new(small_spec());
        let be = p
            .open(&BackendSel { lora_rank: 2, micro_batch: None, seed: 1 })
            .unwrap();
        assert_eq!(be.config().lora_rank, 2);
        assert_eq!(be.param("b01_lora_aq").unwrap().shape(), &[2, 16, 2]);
        assert_eq!(be.param("b01_lora_bv").unwrap().shape(), &[2, 2, 8]);
        assert!(p
            .open(&BackendSel { lora_rank: 3, micro_batch: None, seed: 1 })
            .is_err());
    }

    #[test]
    fn step_trains_and_is_deterministic() {
        let spec = small_spec();
        let p = NativeProvider::new(spec.clone());
        let (x, y) = sample(&spec, 2, 3);
        let masks = MaskPair::ones(2, 2);
        let mut a = p.open(&BackendSel::full(7)).unwrap();
        let mut b = p.open(&BackendSel::full(7)).unwrap();
        let first = a.step(&x, &y, &masks, 0.1).unwrap();
        assert!(first.loss.is_finite() && first.loss > 0.0);
        // Same seed + same data -> bitwise identical trajectory.
        let fb = b.step(&x, &y, &masks, 0.1).unwrap();
        assert_eq!(first.loss, fb.loss);
        // Repeated steps on one micro-batch overfit it.
        let mut last = first.loss;
        for _ in 0..30 {
            last = a.step(&x, &y, &masks, 0.1).unwrap().loss;
        }
        assert!(
            last < first.loss * 0.5,
            "loss should collapse on a repeated batch: {} -> {last}",
            first.loss
        );
    }

    #[test]
    fn eval_matches_step_loss_at_lr_zero() {
        let spec = small_spec();
        let p = NativeProvider::new(spec.clone());
        let (x, y) = sample(&spec, 2, 4);
        let masks = MaskPair::ones(2, 2);
        let mut be = p.open(&BackendSel::full(9)).unwrap();
        let ev = be.eval(&x, &y, None).unwrap();
        let st = be.step(&x, &y, &masks, 0.0).unwrap();
        assert_eq!(ev.loss, st.loss, "same forward path");
        assert_eq!(ev.n_correct, st.n_correct);
        // lr = 0 must not move parameters.
        let before = be.param("b00_wqkv").unwrap();
        be.step(&x, &y, &masks, 0.0).unwrap();
        assert_eq!(before, be.param("b00_wqkv").unwrap());
    }

    #[test]
    fn probe_shape_and_positivity() {
        let spec = small_spec();
        let p = NativeProvider::new(spec.clone());
        let (x, y) = sample(&spec, 2, 5);
        let be = p.open(&BackendSel::full(11)).unwrap();
        let probe = be.score_probe(&x, &y).unwrap();
        assert_eq!(probe.shape(), &[2, 2, 4]);
        assert!(probe.data().iter().all(|&v| v >= 0.0));
        for l in 0..2 {
            for h in 0..2 {
                assert!(probe.at(&[l, h, 3]) > 0.0, "weight magnitude strictly positive");
            }
        }
    }
}
