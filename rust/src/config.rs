//! Builder-style construction for every run-configuration struct, plus
//! [`JobSpec`] — the serialized twin of [`TrainerConfigBuilder`] that
//! the CLI `--config` path and the serve control plane share.
//!
//! [`crate::coordinator::TrainerConfig`], `crate::dist::DistConfig`,
//! and [`crate::backend::native::NativeSpec`] are `#[non_exhaustive]`
//! pub-field structs: readable anywhere, *constructed* only here. Every
//! in-repo construction site — `main.rs`, tests, benches, examples, the
//! experiments, and the multi-tenant service — goes through a builder,
//! so defaults live in exactly one place and validation runs at
//! `build()` instead of deep inside a training loop. This module is the
//! single home of the bare struct literals (the grep-clean contract
//! pinned by the API-redesign issue).

use anyhow::Result;

#[cfg(feature = "native")]
use crate::backend::native::NativeSpec;
use crate::cluster::{ExecMode, HeteroSpec};
use crate::coordinator::{SchedulerKind, TrainerConfig, UpdateMode};
use crate::data::SyntheticKind;
#[cfg(feature = "native")]
use crate::dist::DistConfig;
#[cfg(feature = "native")]
use crate::runtime::ModelConfig;
use crate::schedule::Budget;
use crate::scores::ScoreConfig;
use crate::util::json::{num, obj, s, Json};

// ---------------------------------------------------------------------------
// TrainerConfig builder
// ---------------------------------------------------------------------------

/// Builder for [`TrainerConfig`]. Starts from the quick-run defaults
/// (the values `TrainerConfig::quick` has always used); every setter
/// overrides one knob; [`TrainerConfigBuilder::build`] validates the
/// combination and returns the frozen config.
#[derive(Clone, Debug)]
pub struct TrainerConfigBuilder {
    cfg: TrainerConfig,
}

impl Default for TrainerConfigBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainerConfigBuilder {
    /// Builder seeded with the quick-run defaults: cifar10-like data,
    /// the D2FT scheduler at the paper's 3+1-of-5 budget, 480/120
    /// train/test examples, 24 batches after 12 pretrain batches.
    pub fn new() -> TrainerConfigBuilder {
        TrainerConfigBuilder {
            // The one TrainerConfig literal in the repo.
            cfg: TrainerConfig {
                dataset: SyntheticKind::Cifar10Like,
                train_size: 480,
                test_size: 120,
                micros_per_batch: 5,
                batches: 24,
                lr: 0.03,
                budget: Budget::uniform(5, 3, 1),
                scheduler: SchedulerKind::D2ft,
                scores: ScoreConfig::default(),
                // A bounded pool: the trainer runs the engine at its
                // accounting operating point, where per-device threads
                // (the `--workers 0` paper placement) buy nothing over a
                // small pool — results are bitwise identical either way.
                exec: ExecMode::Parallel { workers: 8 },
                partition_group: 1,
                hetero: None,
                seed: 17,
                pretrain_batches: 12,
                eval_every: 0,
                lora_rank: 0,
                micro_batch: None,
                update: UpdateMode::PerMicro,
            },
        }
    }

    /// Synthetic dataset preset to fine-tune on.
    pub fn dataset(mut self, v: SyntheticKind) -> Self {
        self.cfg.dataset = v;
        self
    }

    /// Training examples to generate.
    pub fn train_size(mut self, v: usize) -> Self {
        self.cfg.train_size = v;
        self
    }

    /// Test examples to generate.
    pub fn test_size(mut self, v: usize) -> Self {
        self.cfg.test_size = v;
        self
    }

    /// Micro-batches per batch (paper: 5).
    pub fn micros_per_batch(mut self, v: usize) -> Self {
        self.cfg.micros_per_batch = v;
        self
    }

    /// Fine-tuning batches to run.
    pub fn batches(mut self, v: usize) -> Self {
        self.cfg.batches = v;
        self
    }

    /// SGD-momentum learning rate.
    pub fn lr(mut self, v: f32) -> Self {
        self.cfg.lr = v;
        self
    }

    /// Per-device operation budget.
    pub fn budget(mut self, v: Budget) -> Self {
        self.cfg.budget = v;
        self
    }

    /// Scheduling policy (D2FT or a baseline).
    pub fn scheduler(mut self, v: SchedulerKind) -> Self {
        self.cfg.scheduler = v;
        self
    }

    /// Contribution metrics feeding the bi-level knapsack.
    pub fn scores(mut self, v: ScoreConfig) -> Self {
        self.cfg.scores = v;
        self
    }

    /// Cluster execution mode (parallel engine or serial reference).
    pub fn exec(mut self, v: ExecMode) -> Self {
        self.cfg.exec = v;
        self
    }

    /// Head-group size for the partition (1 = per-head).
    pub fn partition_group(mut self, v: usize) -> Self {
        self.cfg.partition_group = v;
        self
    }

    /// Device heterogeneity configuration (`None` = homogeneous).
    pub fn hetero(mut self, v: Option<HeteroSpec>) -> Self {
        self.cfg.hetero = v;
        self
    }

    /// Run seed (data order, random baselines, parameter init).
    pub fn seed(mut self, v: u64) -> Self {
        self.cfg.seed = v;
        self
    }

    /// Synthetic pre-training batches before fine-tuning.
    pub fn pretrain_batches(mut self, v: usize) -> Self {
        self.cfg.pretrain_batches = v;
        self
    }

    /// Evaluate every N batches (0 = only at the end).
    pub fn eval_every(mut self, v: usize) -> Self {
        self.cfg.eval_every = v;
        self
    }

    /// LoRA adapter rank (0 = full fine-tuning).
    pub fn lora_rank(mut self, v: usize) -> Self {
        self.cfg.lora_rank = v;
        self
    }

    /// Open the backend at a micro-batch-size *variant* trainstep
    /// (Table VI) instead of the provider default — this absorbs the
    /// old `Trainer::new_with_micro_batch` entry point.
    pub fn micro_batch(mut self, v: usize) -> Self {
        self.cfg.micro_batch = Some(v);
        self
    }

    /// Update semantics: per-micro (sequential) or batch-accumulated
    /// (the data-parallel reference the dist runtime distributes).
    pub fn update(mut self, v: UpdateMode) -> Self {
        self.cfg.update = v;
        self
    }

    /// Validate the combination and freeze it into a [`TrainerConfig`].
    pub fn build(self) -> Result<TrainerConfig> {
        let c = &self.cfg;
        anyhow::ensure!(c.train_size > 0, "train_size must be >= 1");
        anyhow::ensure!(c.test_size > 0, "test_size must be >= 1");
        anyhow::ensure!(c.micros_per_batch > 0, "micros_per_batch must be >= 1");
        anyhow::ensure!(
            c.lr.is_finite() && c.lr > 0.0,
            "lr must be a positive finite number, got {}",
            c.lr
        );
        anyhow::ensure!(
            c.budget.n_full + c.budget.n_fwd <= c.budget.n_micro,
            "budget ({} p_f + {} p_o) exceeds its {} micro-batches",
            c.budget.n_full,
            c.budget.n_fwd,
            c.budget.n_micro
        );
        if let Some(mb) = c.micro_batch {
            anyhow::ensure!(mb > 0, "micro_batch variant must be >= 1");
        }
        Ok(self.cfg)
    }
}

// ---------------------------------------------------------------------------
// NativeSpec builder
// ---------------------------------------------------------------------------

/// Builder for [`NativeSpec`]. Starts from a preset (default
/// [`NativeSpec::tiny`]) and overrides individual fields — the form the
/// tests use to shrink the model without writing a struct literal.
#[cfg(feature = "native")]
#[derive(Clone, Debug, Default)]
pub struct NativeSpecBuilder {
    spec: NativeSpec,
}

#[cfg(feature = "native")]
impl NativeSpecBuilder {
    /// Builder seeded with [`NativeSpec::tiny`].
    pub fn new() -> NativeSpecBuilder {
        NativeSpecBuilder { spec: NativeSpec::tiny() }
    }

    /// Builder seeded with a named preset (`mini`/`tiny` or `small`).
    pub fn preset(name: &str) -> Result<NativeSpecBuilder> {
        Ok(NativeSpecBuilder { spec: NativeSpec::preset(name)? })
    }

    /// Replace the model configuration wholesale.
    pub fn config(mut self, mc: ModelConfig) -> Self {
        self.spec.config = mc;
        self
    }

    /// Default trainstep micro-batch size.
    pub fn micro_batch(mut self, v: usize) -> Self {
        self.spec.micro_batch = v;
        self
    }

    /// Alternative micro-batch sizes advertised for Table VI.
    pub fn mb_variants(mut self, v: Vec<usize>) -> Self {
        self.spec.mb_variants = v;
        self
    }

    /// LoRA ranks the provider advertises.
    pub fn lora_ranks(mut self, v: Vec<usize>) -> Self {
        self.spec.lora_ranks = v;
        self
    }

    /// The rank used by default for LoRA experiments.
    pub fn lora_standard_rank(mut self, v: usize) -> Self {
        self.spec.lora_standard_rank = v;
        self
    }

    /// Base seed mixed into parameter initialization.
    pub fn init_seed(mut self, v: u64) -> Self {
        self.spec.init_seed = v;
        self
    }

    /// Kernel threads for the matmul row-parallel path (0 = auto).
    pub fn threads(mut self, v: usize) -> Self {
        self.spec.threads = v;
        self
    }

    /// Validate and freeze into a [`NativeSpec`].
    pub fn build(self) -> Result<NativeSpec> {
        let sp = &self.spec;
        anyhow::ensure!(sp.micro_batch > 0, "micro_batch must be >= 1");
        anyhow::ensure!(
            sp.config.img_size % sp.config.patch == 0,
            "img_size {} must be divisible by patch {}",
            sp.config.img_size,
            sp.config.patch
        );
        anyhow::ensure!(sp.config.depth > 0 && sp.config.heads > 0, "model needs >= 1 block/head");
        Ok(self.spec)
    }
}

// ---------------------------------------------------------------------------
// DistConfig builder
// ---------------------------------------------------------------------------

/// Builder for `DistConfig`. Seeded with a [`TrainerConfig`] and the
/// default cluster knobs (channel transport, overlap on, lossless f32
/// wire, calibration on); [`DistConfigBuilder::build`] validates.
#[cfg(feature = "native")]
#[derive(Clone, Debug)]
pub struct DistConfigBuilder {
    cfg: DistConfig,
}

#[cfg(feature = "native")]
impl DistConfigBuilder {
    /// Builder over `train` with `workers` replicas and default knobs.
    pub fn new(train: TrainerConfig, workers: usize) -> DistConfigBuilder {
        use crate::dist::{ExchangeMode, TransportKind, WireCompression, WirePrecision};
        DistConfigBuilder {
            // The one DistConfig literal in the repo.
            cfg: DistConfig {
                train,
                workers,
                exchange: ExchangeMode::MaskedAllReduce,
                transport: TransportKind::Channel,
                overlap: true,
                wire_precision: WirePrecision::F32,
                compress: WireCompression::None,
                ring_group: 0,
                sim_wire_ms_per_mib: 0.0,
                calibrate: true,
                heartbeat_ms: 500,
                liveness_misses: 4,
                stall_reassign_ms: 5000,
                batch_timeout_ms: 120_000,
                faults: Vec::new(),
                checkpoint_dir: None,
                checkpoint_every: 1,
                checkpoint_retain: 2,
                resume_from: None,
                halt_after_batch: None,
                trace_out: None,
                metrics: None,
            },
        }
    }

    /// Worker replica count (>= 1).
    pub fn workers(mut self, v: usize) -> Self {
        self.cfg.workers = v;
        self
    }

    /// Gradient exchange topology.
    pub fn exchange(mut self, v: crate::dist::ExchangeMode) -> Self {
        self.cfg.exchange = v;
        self
    }

    /// Frame transport: in-process channels or TCP.
    pub fn transport(mut self, v: crate::dist::TransportKind) -> Self {
        self.cfg.transport = v;
        self
    }

    /// Pipeline encode+upload behind the next task's compute.
    pub fn overlap(mut self, v: bool) -> Self {
        self.cfg.overlap = v;
        self
    }

    /// Gradient payload precision on the wire.
    pub fn wire_precision(mut self, v: crate::dist::WirePrecision) -> Self {
        self.cfg.wire_precision = v;
        self
    }

    /// Lossy payload compression under the precision layer.
    pub fn compress(mut self, v: crate::dist::WireCompression) -> Self {
        self.cfg.compress = v;
        self
    }

    /// Group size for the hierarchical exchange (0 picks ⌈√K⌉).
    pub fn ring_group(mut self, v: usize) -> Self {
        self.cfg.ring_group = v;
        self
    }

    /// Simulated NIC cost (ms per MiB of encoded message).
    pub fn sim_wire_ms_per_mib(mut self, v: f64) -> Self {
        self.cfg.sim_wire_ms_per_mib = v;
        self
    }

    /// Recalibrate the modeled exec-time tables at epoch boundaries.
    pub fn calibrate(mut self, v: bool) -> Self {
        self.cfg.calibrate = v;
        self
    }

    /// Worker heartbeat interval in ms (0 disables liveness eviction).
    pub fn heartbeat_ms(mut self, v: u64) -> Self {
        self.cfg.heartbeat_ms = v;
        self
    }

    /// Missed heartbeat intervals before a silent link is declared dead.
    pub fn liveness_misses(mut self, v: u32) -> Self {
        self.cfg.liveness_misses = v;
        self
    }

    /// Straggler reassignment deadline (ms) on an incomplete barrier.
    pub fn stall_reassign_ms(mut self, v: u64) -> Self {
        self.cfg.stall_reassign_ms = v;
        self
    }

    /// Hard per-batch deadline (ms).
    pub fn batch_timeout_ms(mut self, v: u64) -> Self {
        self.cfg.batch_timeout_ms = v;
        self
    }

    /// Scripted fault plans per worker slot (tests/chaos only).
    pub fn faults(mut self, v: Vec<(usize, crate::dist::FaultPlan)>) -> Self {
        self.cfg.faults = v;
        self
    }

    /// Directory for epoch-boundary checkpoints (`None` disables).
    pub fn checkpoint_dir(mut self, v: Option<std::path::PathBuf>) -> Self {
        self.cfg.checkpoint_dir = v;
        self
    }

    /// Write a checkpoint every N completed epochs (min 1).
    pub fn checkpoint_every(mut self, v: usize) -> Self {
        self.cfg.checkpoint_every = v;
        self
    }

    /// Epoch checkpoints kept after rotation (min 1).
    pub fn checkpoint_retain(mut self, v: usize) -> Self {
        self.cfg.checkpoint_retain = v;
        self
    }

    /// Resume from a checkpoint file or crash-recovery directory.
    pub fn resume_from(mut self, v: Option<std::path::PathBuf>) -> Self {
        self.cfg.resume_from = v;
        self
    }

    /// Crash simulation: stop dead after this many completed batches.
    pub fn halt_after_batch(mut self, v: Option<usize>) -> Self {
        self.cfg.halt_after_batch = v;
        self
    }

    /// Write a merged Chrome trace-event JSON here at the end of the run.
    pub fn trace_out(mut self, v: Option<std::path::PathBuf>) -> Self {
        self.cfg.trace_out = v;
        self
    }

    /// Metrics registry this run publishes into.
    pub fn metrics(mut self, v: Option<std::sync::Arc<crate::obs::metrics::Registry>>) -> Self {
        self.cfg.metrics = v;
        self
    }

    /// Validate the combination and freeze it into a `DistConfig`.
    pub fn build(self) -> Result<DistConfig> {
        let c = &self.cfg;
        anyhow::ensure!(c.workers >= 1, "a dist run needs >= 1 worker replica");
        anyhow::ensure!(c.checkpoint_every >= 1, "checkpoint_every must be >= 1");
        anyhow::ensure!(c.checkpoint_retain >= 1, "checkpoint_retain must be >= 1");
        if c.heartbeat_ms > 0 {
            anyhow::ensure!(
                c.liveness_misses >= 1,
                "liveness_misses must be >= 1 when heartbeats are on"
            );
        }
        Ok(self.cfg)
    }
}

// ---------------------------------------------------------------------------
// JobSpec — the serialized twin of the trainer builder
// ---------------------------------------------------------------------------

/// Current `JobSpec` JSON schema label.
pub const JOB_SPEC_SCHEMA: &str = "d2ft-job-spec-v1";

/// One tenant's fine-tuning request, as data: the serde-free serialized
/// twin of [`TrainerConfigBuilder`]. The CLI's `--config run.json`
/// loads one, `repro job submit` sends one to the serve control plane,
/// and both funnel into [`JobSpec::to_trainer_config`] — a single
/// validated construction path for flags and service submissions.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Tenant identity (meter key; the service enforces `--max-tenants`
    /// distinct values).
    pub tenant: String,
    /// Native model preset the job expects (`tiny` / `small`). The
    /// service rejects jobs whose preset differs from the fleet's.
    pub model: String,
    /// Dataset preset (CLI token: `c10` / `c100` / `cars`).
    pub dataset: SyntheticKind,
    /// Scheduling policy (CLI token, e.g. `d2ft`).
    pub scheduler: SchedulerKind,
    /// LoRA adapter rank. The service requires >= 1 (a rank-0 job is
    /// full fine-tuning — not multiplexable over a shared base).
    pub lora_rank: usize,
    /// Micro-batches per batch.
    pub micros_per_batch: usize,
    /// `p_f` (full) slots per device per batch.
    pub budget_full: usize,
    /// `p_o` (forward-only) slots per device per batch.
    pub budget_fwd: usize,
    /// Step quota: fine-tuning batches the job is entitled to run.
    pub batches: usize,
    /// Synthetic pre-training batches before fine-tuning.
    pub pretrain_batches: usize,
    /// Training examples to generate.
    pub train_size: usize,
    /// Test examples to generate.
    pub test_size: usize,
    /// SGD-momentum learning rate.
    pub lr: f32,
    /// Run seed (data order, baseline randomness, adapter init).
    pub seed: u64,
    /// Admission priority (higher wins; ties break by arrival order).
    pub priority: u32,
}

impl JobSpec {
    /// A small default job for `tenant`: cifar10-like data, rank-2
    /// adapters, the D2FT scheduler at the paper's 3+1-of-5 budget.
    pub fn default_for(tenant: &str) -> JobSpec {
        JobSpec {
            tenant: tenant.to_string(),
            model: "tiny".to_string(),
            dataset: SyntheticKind::Cifar10Like,
            scheduler: SchedulerKind::D2ft,
            lora_rank: 2,
            micros_per_batch: 5,
            budget_full: 3,
            budget_fwd: 1,
            batches: 8,
            pretrain_batches: 2,
            train_size: 80,
            test_size: 16,
            lr: 0.03,
            seed: 17,
            priority: 1,
        }
    }

    /// Serialize for the wire / `--config` file.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", s(JOB_SPEC_SCHEMA)),
            ("tenant", s(&self.tenant)),
            ("model", s(&self.model)),
            ("dataset", s(self.dataset.cli_label())),
            ("scheduler", s(&self.scheduler.cli_label())),
            ("lora_rank", num(self.lora_rank as f64)),
            ("micros_per_batch", num(self.micros_per_batch as f64)),
            ("budget_full", num(self.budget_full as f64)),
            ("budget_fwd", num(self.budget_fwd as f64)),
            ("batches", num(self.batches as f64)),
            ("pretrain_batches", num(self.pretrain_batches as f64)),
            ("train_size", num(self.train_size as f64)),
            ("test_size", num(self.test_size as f64)),
            ("lr", num(self.lr as f64)),
            ("seed", num(self.seed as f64)),
            ("priority", num(self.priority as f64)),
        ])
    }

    /// Deserialize from a parsed JSON document. Every key except
    /// `tenant` is optional and falls back to the
    /// [`JobSpec::default_for`] value, so a `--config` file only states
    /// what it overrides.
    pub fn from_json(doc: &Json) -> Result<JobSpec> {
        let tenant = doc
            .str_at("tenant")
            .map_err(|_| anyhow::anyhow!("job spec needs a \"tenant\" string"))?;
        let mut spec = JobSpec::default_for(&tenant);
        if let Some(v) = doc.opt("model") {
            spec.model = v.as_str()?.to_string();
        }
        if let Some(v) = doc.opt("dataset") {
            spec.dataset = SyntheticKind::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.opt("scheduler") {
            spec.scheduler = SchedulerKind::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.opt("lora_rank") {
            spec.lora_rank = v.as_usize()?;
        }
        if let Some(v) = doc.opt("micros_per_batch") {
            spec.micros_per_batch = v.as_usize()?;
        }
        if let Some(v) = doc.opt("budget_full") {
            spec.budget_full = v.as_usize()?;
        }
        if let Some(v) = doc.opt("budget_fwd") {
            spec.budget_fwd = v.as_usize()?;
        }
        if let Some(v) = doc.opt("batches") {
            spec.batches = v.as_usize()?;
        }
        if let Some(v) = doc.opt("pretrain_batches") {
            spec.pretrain_batches = v.as_usize()?;
        }
        if let Some(v) = doc.opt("train_size") {
            spec.train_size = v.as_usize()?;
        }
        if let Some(v) = doc.opt("test_size") {
            spec.test_size = v.as_usize()?;
        }
        if let Some(v) = doc.opt("lr") {
            spec.lr = v.as_f64()? as f32;
        }
        if let Some(v) = doc.opt("seed") {
            spec.seed = v.as_f64()? as u64;
        }
        if let Some(v) = doc.opt("priority") {
            spec.priority = v.as_f64()? as u32;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Parse from JSON text (the `--config` file / control-plane body).
    pub fn parse(text: &str) -> Result<JobSpec> {
        JobSpec::from_json(&Json::parse(text)?)
    }

    /// Structural validation shared by every entry path.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.tenant.is_empty(), "tenant must be non-empty");
        anyhow::ensure!(self.micros_per_batch >= 1, "micros_per_batch must be >= 1");
        anyhow::ensure!(
            self.budget_full + self.budget_fwd <= self.micros_per_batch,
            "budget ({} p_f + {} p_o) exceeds {} micro-batches",
            self.budget_full,
            self.budget_fwd,
            self.micros_per_batch
        );
        anyhow::ensure!(self.batches >= 1, "step quota (batches) must be >= 1");
        anyhow::ensure!(self.train_size >= 1 && self.test_size >= 1, "dataset sizes must be >= 1");
        anyhow::ensure!(self.lr.is_finite() && self.lr > 0.0, "lr must be positive and finite");
        NativeSpecPresetCheck::check(&self.model)?;
        Ok(())
    }

    /// The per-device operation budget this spec encodes.
    pub fn budget(&self) -> Budget {
        Budget::uniform(self.micros_per_batch, self.budget_full, self.budget_fwd)
    }

    /// Lower into a validated [`TrainerConfig`] via the builder — the
    /// single construction path shared by CLI flags and the service.
    pub fn to_trainer_config(&self) -> Result<TrainerConfig> {
        self.validate()?;
        TrainerConfig::builder()
            .dataset(self.dataset)
            .scheduler(self.scheduler)
            .budget(self.budget())
            .micros_per_batch(self.micros_per_batch)
            .batches(self.batches)
            .pretrain_batches(self.pretrain_batches)
            .train_size(self.train_size)
            .test_size(self.test_size)
            .lr(self.lr)
            .seed(self.seed)
            .lora_rank(self.lora_rank)
            .build()
    }
}

/// Preset-name validation that works with and without the `native`
/// feature (the spec travels through feature-free client code).
struct NativeSpecPresetCheck;

impl NativeSpecPresetCheck {
    fn check(name: &str) -> Result<()> {
        match name.to_ascii_lowercase().as_str() {
            "mini" | "tiny" | "small" | "vit-small" => Ok(()),
            other => anyhow::bail!("unknown model preset {other:?} (mini|small)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trainer_builder_defaults_validate() {
        let cfg = TrainerConfig::builder().build().unwrap();
        assert_eq!(cfg.micros_per_batch, 5);
        assert_eq!(cfg.batches, 24);
        assert_eq!(cfg.update, UpdateMode::PerMicro);
        assert!(cfg.micro_batch.is_none());
    }

    #[test]
    fn trainer_builder_rejects_bad_lr() {
        assert!(TrainerConfig::builder().lr(0.0).build().is_err());
        assert!(TrainerConfig::builder().lr(f32::NAN).build().is_err());
    }

    #[test]
    fn trainer_builder_rejects_overfull_budget() {
        let err = TrainerConfig::builder()
            .budget(Budget { n_micro: 4, n_full: 3, n_fwd: 2, per_device: Vec::new() })
            .build();
        assert!(err.is_err());
    }

    #[test]
    fn job_spec_round_trips_through_json() {
        let mut spec = JobSpec::default_for("alice");
        spec.lora_rank = 4;
        spec.scheduler = SchedulerKind::Random;
        spec.dataset = SyntheticKind::CarsLike;
        spec.priority = 9;
        let text = spec.to_json().to_string_pretty();
        let back = JobSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn job_spec_partial_json_fills_defaults() {
        let back = JobSpec::parse(r#"{"tenant":"bob","batches":3}"#).unwrap();
        assert_eq!(back.tenant, "bob");
        assert_eq!(back.batches, 3);
        assert_eq!(back.lora_rank, JobSpec::default_for("bob").lora_rank);
    }

    #[test]
    fn job_spec_rejects_missing_tenant_and_bad_budget() {
        assert!(JobSpec::parse(r#"{"batches":3}"#).is_err());
        assert!(JobSpec::parse(r#"{"tenant":"x","budget_full":9}"#).is_err());
    }

    #[test]
    fn job_spec_lowers_through_the_builder() {
        let cfg = JobSpec::default_for("alice").to_trainer_config().unwrap();
        assert_eq!(cfg.lora_rank, 2);
        assert_eq!(cfg.batches, 8);
        assert_eq!(cfg.budget.n_full, 3);
    }

    #[cfg(feature = "native")]
    #[test]
    fn dist_builder_defaults_and_validation() {
        let train = TrainerConfig::builder().build().unwrap();
        let d = DistConfig::builder(train.clone(), 3).build().unwrap();
        assert_eq!(d.workers, 3);
        assert!(d.overlap);
        assert!(DistConfig::builder(train, 0).build().is_err());
    }

    #[cfg(feature = "native")]
    #[test]
    fn native_spec_builder_checks_patch_divisibility() {
        let mut mc = NativeSpec::tiny().config;
        mc.img_size = 10;
        mc.patch = 4;
        assert!(NativeSpec::builder().config(mc).build().is_err());
    }
}
