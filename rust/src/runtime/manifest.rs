//! Artifact manifest: the ABI between aot.py and the rust runtime.
//!
//! `manifest.json` records the model config, the micro-batch size baked
//! into each trainstep HLO, the artifact file map, and the parameter
//! table in jax's dict-flatten (sorted-key) order — which is exactly the
//! HLO entry-parameter order.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Mirror of `ViTConfig` on the python side.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Input image side length.
    pub img_size: usize,
    /// Patch side length.
    pub patch: usize,
    /// Embedding dimension.
    pub dim: usize,
    /// Transformer depth (blocks).
    pub depth: usize,
    /// Attention heads per block.
    pub heads: usize,
    /// FFN hidden size as a multiple of `dim`.
    pub mlp_ratio: usize,
    /// Classifier output classes.
    pub classes: usize,
    /// LoRA rank (0 = full fine-tuning artifact set).
    pub lora_rank: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Sequence length (patches + CLS).
    pub tokens: usize,
}

impl ModelConfig {
    /// Number of (block, head) subnets in the transformer body.
    pub fn body_subnets(&self) -> usize {
        self.depth * self.heads
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelConfig {
            img_size: j.usize_at("img_size")?,
            patch: j.usize_at("patch")?,
            dim: j.usize_at("dim")?,
            depth: j.usize_at("depth")?,
            heads: j.usize_at("heads")?,
            mlp_ratio: j.usize_at("mlp_ratio")?,
            classes: j.usize_at("classes")?,
            lora_rank: j.usize_at("lora_rank")?,
            head_dim: j.usize_at("head_dim")?,
            tokens: j.usize_at("tokens")?,
        })
    }
}

/// One tensor in the flat parameter blob.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    /// Flattened parameter name (jax dict-flatten key).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Element count (product of `shape`).
    pub size: usize,
    /// Offset in *elements* (not bytes) into the blob.
    pub offset: usize,
}

/// One artifact set's manifest (model config + artifact map + params).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Preset file-name prefix (empty for the full-FT set).
    pub prefix: String,
    /// The model configuration the artifacts were lowered for.
    pub config: ModelConfig,
    /// Micro-batch size baked into the trainstep HLO.
    pub micro_batch: usize,
    /// Alternative micro-batch sizes with lowered variants (Table VI).
    pub mb_variants: Vec<usize>,
    /// artifact kind -> file name (relative to the artifacts dir).
    pub artifacts: Vec<(String, String)>,
    /// File name of the init-parameter blob.
    pub params_bin: String,
    /// Total f32 elements in the blob.
    pub total_elems: usize,
    /// Parameter table in HLO entry-parameter order.
    pub params: Vec<ParamEntry>,
}

impl Manifest {
    /// Load and validate a `manifest.json`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let config = ModelConfig::from_json(j.get("config")?)?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.str_at("name")?,
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    size: p.usize_at("size")?,
                    offset: p.usize_at("offset")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j
            .get("artifacts")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<Vec<_>>>()?;
        let mb_variants = j
            .get("mb_variants")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            prefix: j.str_at("preset_prefix")?,
            config,
            micro_batch: j.usize_at("micro_batch")?,
            mb_variants,
            artifacts,
            params_bin: j.str_at("params_bin")?,
            total_elems: j.usize_at("total_elems")?,
            params,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural invariants the runtime depends on.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for p in &self.params {
            anyhow::ensure!(
                p.offset == off,
                "param {} offset {} != expected {off}",
                p.name,
                p.offset
            );
            anyhow::ensure!(
                p.shape.iter().product::<usize>() == p.size,
                "param {} shape/size mismatch",
                p.name
            );
            off += p.size;
        }
        anyhow::ensure!(off == self.total_elems, "total_elems mismatch");
        let mut names: Vec<&str> = self.params.iter().map(|p| p.name.as_str()).collect();
        let orig = names.clone();
        names.sort_unstable();
        anyhow::ensure!(orig == names, "params not in sorted (flatten) order");
        Ok(())
    }

    /// File name of the artifact of `kind` (trainstep, eval, scores, ...).
    pub fn artifact(&self, kind: &str) -> Result<&str> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, v)| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("artifact kind {kind:?} not in manifest"))
    }

    /// Number of parameter tensors.
    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn sample_manifest_json() -> String {
        r#"{
          "preset_prefix": "",
          "config": {"img_size": 16, "patch": 4, "dim": 48, "depth": 3,
                     "heads": 4, "mlp_ratio": 4, "classes": 10,
                     "lora_rank": 0, "head_dim": 12, "tokens": 17},
          "micro_batch": 4,
          "mb_variants": [2],
          "artifacts": {"trainstep": "trainstep.hlo.txt", "eval": "eval.hlo.txt"},
          "params_bin": "params_init.bin",
          "n_params": 2,
          "total_elems": 14,
          "params": [
            {"name": "a_cls", "shape": [1, 1, 8], "size": 8, "offset": 0},
            {"name": "z_b", "shape": [6], "size": 6, "offset": 8}
          ],
          "trainstep_io": {"inputs": "", "outputs": ""}
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join("d2ft_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(sample_manifest_json().as_bytes()).unwrap();
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.config.depth, 3);
        assert_eq!(m.config.body_subnets(), 12);
        assert_eq!(m.micro_batch, 4);
        assert_eq!(m.artifact("eval").unwrap(), "eval.hlo.txt");
        assert!(m.artifact("nope").is_err());
        assert_eq!(m.params[1].offset, 8);
    }

    #[test]
    fn rejects_bad_offsets() {
        let text = sample_manifest_json().replace("\"offset\": 8", "\"offset\": 9");
        let dir = std::env::temp_dir().join("d2ft_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        std::fs::write(&path, text).unwrap();
        assert!(Manifest::load(&path).is_err());
    }
}
