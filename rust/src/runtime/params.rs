//! ParamStore: the flat f32 parameter blob + per-tensor views. With the
//! `xla` feature, also the `Literal` conversion used to feed the
//! trainstep executable.

use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::{Manifest, ParamEntry};
use crate::tensor::Tensor;

/// All model parameters as one contiguous f32 buffer, sliced per tensor
/// according to the manifest. Momentum buffers share the layout.
pub struct ParamStore {
    entries: Vec<ParamEntry>,
    flat: Vec<f32>,
}

impl ParamStore {
    /// Load `params_init.bin` next to the manifest.
    pub fn load(manifest: &Manifest, artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join(&manifest.params_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading params blob {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == manifest.total_elems * 4,
            "blob {} has {} bytes, manifest expects {}",
            path.display(),
            bytes.len(),
            manifest.total_elems * 4
        );
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(ParamStore { entries: manifest.params.clone(), flat })
    }

    /// Zero-initialized store with the same layout (momentum buffers).
    pub fn zeros_like(manifest: &Manifest) -> Self {
        ParamStore {
            entries: manifest.params.clone(),
            flat: vec![0.0; manifest.total_elems],
        }
    }

    /// Build a store from an explicit entry table + flat buffer (the
    /// native backend's export path). Validates the same structural
    /// invariants a manifest does: contiguous ascending offsets,
    /// shape/size agreement, and a buffer of exactly the summed size.
    pub fn from_parts(entries: Vec<ParamEntry>, flat: Vec<f32>) -> Result<Self> {
        let mut off = 0;
        for e in &entries {
            anyhow::ensure!(
                e.offset == off,
                "param {} offset {} != expected {off}",
                e.name,
                e.offset
            );
            anyhow::ensure!(
                e.shape.iter().product::<usize>() == e.size,
                "param {} shape/size mismatch",
                e.name
            );
            off += e.size;
        }
        anyhow::ensure!(
            off == flat.len(),
            "flat buffer has {} elements, entries expect {off}",
            flat.len()
        );
        Ok(ParamStore { entries, flat })
    }

    /// The whole flat element buffer (manifest order).
    pub fn flat(&self) -> &[f32] {
        &self.flat
    }

    /// Write the blob in `params_init.bin` format (little-endian f32) —
    /// the file an artifact set ships, so a native export can seed the
    /// XLA path with identical bits.
    pub fn write_blob(&self, path: &Path) -> Result<()> {
        let bytes: Vec<u8> = self.flat.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(path, bytes)
            .with_context(|| format!("writing params blob {}", path.display()))
    }

    /// Number of parameter tensors.
    pub fn n_tensors(&self) -> usize {
        self.entries.len()
    }

    /// Total f32 elements across all tensors.
    pub fn total_elems(&self) -> usize {
        self.flat.len()
    }

    /// The parameter table, in manifest order.
    pub fn entries(&self) -> &[ParamEntry] {
        &self.entries
    }

    /// Look up one tensor's entry by name.
    pub fn entry(&self, name: &str) -> Option<&ParamEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Flat slice for one tensor.
    pub fn slice(&self, name: &str) -> Option<&[f32]> {
        let e = self.entry(name)?;
        Some(&self.flat[e.offset..e.offset + e.size])
    }

    /// Copy of one tensor.
    pub fn tensor(&self, name: &str) -> Option<Tensor> {
        let e = self.entry(name)?;
        Some(Tensor::from_vec(
            &e.shape,
            self.flat[e.offset..e.offset + e.size].to_vec(),
        ))
    }

    /// Build the per-tensor `xla::Literal` argument vector, in manifest
    /// (== HLO parameter) order.
    #[cfg(feature = "xla")]
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.entries
            .iter()
            .map(|e| {
                let slice = &self.flat[e.offset..e.offset + e.size];
                let dims: Vec<i64> = e.shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(slice).reshape(&dims)?)
            })
            .collect()
    }

    /// Overwrite the blob from per-tensor literals (post-step write-back).
    #[cfg(feature = "xla")]
    pub fn from_literals(&mut self, literals: &[xla::Literal]) -> Result<()> {
        anyhow::ensure!(literals.len() == self.entries.len(), "literal count mismatch");
        for (e, lit) in self.entries.iter().zip(literals) {
            let v = lit.to_vec::<f32>()?;
            anyhow::ensure!(v.len() == e.size, "size mismatch for {}", e.name);
            self.flat[e.offset..e.offset + e.size].copy_from_slice(&v);
        }
        Ok(())
    }

    /// Sum of |w| per tensor-name predicate (weight-magnitude scores use
    /// per-subnet slices computed in the HLO probe; this host-side variant
    /// backs tests and the dynamic-pruning baselines).
    pub fn abs_sum_where(&self, pred: impl Fn(&str) -> bool) -> f64 {
        self.entries
            .iter()
            .filter(|e| pred(&e.name))
            .map(|e| {
                self.flat[e.offset..e.offset + e.size]
                    .iter()
                    .map(|&x| (x as f64).abs())
                    .sum::<f64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelConfig;

    fn tiny_manifest() -> Manifest {
        Manifest {
            prefix: String::new(),
            config: ModelConfig {
                img_size: 16,
                patch: 4,
                dim: 8,
                depth: 1,
                heads: 2,
                mlp_ratio: 4,
                classes: 4,
                lora_rank: 0,
                head_dim: 4,
                tokens: 17,
            },
            micro_batch: 2,
            mb_variants: vec![],
            artifacts: vec![],
            params_bin: "p.bin".into(),
            total_elems: 10,
            params: vec![
                ParamEntry { name: "a".into(), shape: vec![2, 3], size: 6, offset: 0 },
                ParamEntry { name: "b".into(), shape: vec![4], size: 4, offset: 6 },
            ],
        }
    }

    #[test]
    fn load_and_slice() {
        let dir = std::env::temp_dir().join("d2ft_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = tiny_manifest();
        let data: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("p.bin"), bytes).unwrap();
        let store = ParamStore::load(&m, &dir).unwrap();
        assert_eq!(store.slice("a").unwrap(), &data[..6]);
        assert_eq!(store.slice("b").unwrap(), &data[6..]);
        assert_eq!(store.tensor("a").unwrap().shape(), &[2, 3]);
        assert!(store.slice("nope").is_none());
        assert_eq!(store.abs_sum_where(|n| n == "b"), (6..10).sum::<usize>() as f64);
    }

    #[test]
    fn zeros_like_layout() {
        let m = tiny_manifest();
        let z = ParamStore::zeros_like(&m);
        assert_eq!(z.total_elems(), 10);
        assert!(z.slice("a").unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_parts_validates_and_round_trips_blob() {
        let entries = vec![
            ParamEntry { name: "a".into(), shape: vec![2, 2], size: 4, offset: 0 },
            ParamEntry { name: "b".into(), shape: vec![3], size: 3, offset: 4 },
        ];
        let flat: Vec<f32> = vec![1.0, -2.5, 3.0, 0.25, -0.0, 7.0, 1e-9];
        let store = ParamStore::from_parts(entries.clone(), flat.clone()).unwrap();
        assert_eq!(store.flat(), &flat[..]);
        assert_eq!(store.slice("b").unwrap(), &flat[4..]);
        // Bad offset rejected.
        let mut bad = entries.clone();
        bad[1].offset = 5;
        assert!(ParamStore::from_parts(bad, flat.clone()).is_err());
        // Short buffer rejected.
        assert!(ParamStore::from_parts(entries.clone(), flat[..6].to_vec()).is_err());
        // write_blob -> load round trip is bitwise.
        let dir = std::env::temp_dir().join("d2ft_params_test3");
        std::fs::create_dir_all(&dir).unwrap();
        store.write_blob(&dir.join("p.bin")).unwrap();
        let m = Manifest {
            prefix: String::new(),
            config: tiny_manifest().config,
            micro_batch: 2,
            mb_variants: vec![],
            artifacts: vec![],
            params_bin: "p.bin".into(),
            total_elems: 7,
            params: entries,
        };
        let loaded = ParamStore::load(&m, &dir).unwrap();
        assert_eq!(
            loaded.flat().iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            flat.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rejects_wrong_blob_size() {
        let dir = std::env::temp_dir().join("d2ft_params_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("p.bin"), [0u8; 12]).unwrap();
        assert!(ParamStore::load(&tiny_manifest(), &dir).is_err());
    }
}
