//! PJRT runtime: load AOT artifacts (HLO text), compile once per process,
//! execute from the training hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).

mod artifacts;
mod manifest;
mod params;
mod session;

pub use artifacts::ArtifactRegistry;
pub use manifest::{Manifest, ModelConfig, ParamEntry};
pub use params::ParamStore;
pub use session::{EvalOut, Session, StepOut, TrainState};
