//! Model/runtime metadata — and, behind the optional `xla` feature, the
//! PJRT runtime that loads AOT artifacts (HLO text), compiles once per
//! process, and executes from the training hot path.
//!
//! The always-available half ([`Manifest`], [`ModelConfig`],
//! [`ParamStore`]) is pure Rust: the model-configuration and parameter
//! bookkeeping every backend shares. The XLA half follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id
//! protos that xla_extension 0.5.1 rejects; the text parser reassigns
//! ids).

#[cfg(feature = "xla")]
mod artifacts;
mod manifest;
mod params;
#[cfg(feature = "xla")]
mod session;

#[cfg(feature = "xla")]
pub use artifacts::ArtifactRegistry;
pub use manifest::{Manifest, ModelConfig, ParamEntry};
pub use params::ParamStore;
#[cfg(feature = "xla")]
pub use session::{Session, TrainState};
