//! ArtifactRegistry: locate + lazily compile AOT artifacts.
//!
//! Compilation (HLO text parse + XLA compile) happens once per artifact
//! per process; the training hot path only calls `execute`.
//!
//! PJRT handles in the `xla` crate are `Rc`-based (not `Send`): all
//! numerics execute on the runtime thread, and the cluster's "devices"
//! are a virtual-clock simulation (see `cluster/`), exactly mirroring the
//! paper's cost model.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::Manifest;
use crate::util::json::Json;

/// Top-level view of an `artifacts/` directory (reads `index.json`).
pub struct ArtifactRegistry {
    dir: PathBuf,
    /// Preset name the artifacts were lowered for.
    pub preset: String,
    /// The full fine-tuning artifact set's manifest.
    pub full_manifest: Manifest,
    /// LoRA ranks with lowered artifact sets.
    pub lora_ranks: Vec<usize>,
    /// The rank used by default for LoRA experiments.
    pub lora_standard_rank: usize,
    lora_manifests: HashMap<usize, Manifest>,
    client: xla::PjRtClient,
    compiled: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    /// Open `dir` (default `artifacts/`); compiles nothing yet.
    pub fn open(dir: &Path) -> Result<Self> {
        let index_path = dir.join("index.json");
        let text = std::fs::read_to_string(&index_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                index_path.display()
            )
        })?;
        let j = Json::parse(&text)?;
        let full_manifest = Manifest::load(&dir.join(j.str_at("full")?))?;
        let lora_ranks: Vec<usize> = j
            .get("lora_ranks")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        let mut lora_manifests = HashMap::new();
        if !lora_ranks.is_empty() {
            let lm = j.get("lora_manifests")?.as_obj()?;
            for (rank, path) in lm {
                let r: usize = rank.parse()?;
                lora_manifests.insert(r, Manifest::load(&dir.join(path.as_str()?))?);
            }
        }
        let client = xla::PjRtClient::cpu().map_err(anyhow::Error::msg)?;
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            preset: j.str_at("preset")?,
            full_manifest,
            lora_ranks,
            lora_standard_rank: j.usize_at("lora_standard_rank").unwrap_or(0),
            lora_manifests,
            client,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    /// Conventional location: `$D2FT_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("D2FT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(Path::new(&dir))
    }

    /// The artifacts directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The PJRT CPU client all executables compile against.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Manifest of the LoRA artifact set at `rank`.
    pub fn lora_manifest(&self, rank: usize) -> Result<&Manifest> {
        self.lora_manifests
            .get(&rank)
            .ok_or_else(|| anyhow::anyhow!("no LoRA manifest for rank {rank}"))
    }

    /// Compile (or fetch cached) an artifact by file name.
    pub fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        crate::info!("compiling artifact {}", path.display());
        let t0 = std::time::Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(anyhow::Error::msg)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(anyhow::Error::msg)?);
        crate::info!("compiled {} in {:.2}s", file, t0.elapsed().as_secs_f64());
        self.compiled.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile an artifact referenced by manifest kind.
    pub fn executable_for(
        &self,
        manifest: &Manifest,
        kind: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        self.executable(manifest.artifact(kind)?)
    }
}
