//! Session: typed execute wrappers around the AOT artifacts.
//!
//! One `Session` owns the compiled executables for a manifest (trainstep,
//! eval, scores) plus the mutable training state (params + momentum as
//! per-tensor literals). The hot path is `Session::step`: exactly one
//! PJRT execute for fwd + bwd + SGD-momentum update.

use std::rc::Rc;

use anyhow::Result;

use super::artifacts::ArtifactRegistry;
use super::manifest::Manifest;
use super::params::ParamStore;
use crate::backend::{EvalOut, StepOut};
use crate::schedule::table::MaskPair;
use crate::tensor::Tensor;

/// Mutable training state: params + momentum in HLO parameter order.
pub struct TrainState {
    /// Model parameters as per-tensor literals.
    pub params: Vec<xla::Literal>,
    /// SGD momentum buffers (same layout as `params`).
    pub momentum: Vec<xla::Literal>,
    n: usize,
}

impl TrainState {
    /// Fresh state: the store's parameters + zero momentum.
    pub fn new(store: &ParamStore) -> Result<Self> {
        let params = store.to_literals()?;
        let momentum: Vec<xla::Literal> = store
            .entries()
            .iter()
            .map(|e| {
                let dims: Vec<i64> = e.shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(&vec![0.0f32; e.size]).reshape(&dims)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let n = params.len();
        Ok(TrainState { params, momentum, n })
    }

    /// Number of parameter tensors.
    pub fn n_tensors(&self) -> usize {
        self.n
    }

    /// Zero the momentum buffers (fresh optimizer state — used at the
    /// pretrain -> fine-tune boundary).
    pub fn reset_momentum(&mut self) -> Result<()> {
        for m in self.momentum.iter_mut() {
            let shape = m.array_shape()?;
            let n: usize = shape.dims().iter().map(|&d| d as usize).product();
            let dims: Vec<i64> = shape.dims().to_vec();
            *m = xla::Literal::vec1(&vec![0.0f32; n]).reshape(&dims)?;
        }
        Ok(())
    }

    /// Copy current params back into a ParamStore (for host inspection).
    pub fn write_back(&self, store: &mut ParamStore) -> Result<()> {
        store.from_literals(&self.params)
    }
}

/// Compiled executables + model metadata for one manifest.
///
/// The score-probe executable compiles lazily on first use — it is the
/// most expensive artifact to compile and schedulers that ignore
/// contribution scores (Standard, Random) never touch it.
pub struct Session<'a> {
    registry: &'a ArtifactRegistry,
    /// The manifest this session's executables were compiled from.
    pub manifest: &'a Manifest,
    trainstep: Rc<xla::PjRtLoadedExecutable>,
    eval: Rc<xla::PjRtLoadedExecutable>,
    scores: std::cell::RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
}

impl<'a> Session<'a> {
    /// Compile (or fetch cached) the trainstep + eval executables.
    pub fn new(registry: &'a ArtifactRegistry, manifest: &'a Manifest) -> Result<Self> {
        Ok(Session {
            registry,
            manifest,
            trainstep: registry.executable_for(manifest, "trainstep")?,
            eval: registry.executable_for(manifest, "eval")?,
            scores: std::cell::RefCell::new(None),
        })
    }

    /// Session over a micro-batch-size variant trainstep (Table VI).
    pub fn with_trainstep_variant(mut self, mb: usize) -> Result<Self> {
        let kind = format!("trainstep_mb{mb}");
        self.trainstep = self.registry.executable_for(self.manifest, &kind)?;
        Ok(self)
    }

    fn mask_literal(mask: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = mask.shape().iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(mask.data()).reshape(&dims)?)
    }

    /// Images -> literal ([mb, img, img, 3] f32).
    pub fn x_literal(&self, x: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = x.shape().iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(x.data()).reshape(&dims)?)
    }

    /// Labels -> literal ([mb] s32).
    pub fn y_literal(&self, y: &[i32]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(y))
    }

    /// One fused fwd+bwd+SGD step on a micro-batch under a schedule row.
    /// Exactly one PJRT execute; updates `state` in place.
    pub fn step(
        &self,
        state: &mut TrainState,
        x: &xla::Literal,
        y: &xla::Literal,
        masks: &MaskPair,
        lr: f32,
    ) -> Result<StepOut> {
        let fwd = Self::mask_literal(&masks.fwd)?;
        let bwd = Self::mask_literal(&masks.bwd)?;
        let lr_lit = xla::Literal::scalar(lr);
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(2 * state.n_tensors() + 5);
        args.extend(state.params.iter());
        args.extend(state.momentum.iter());
        args.push(x);
        args.push(y);
        args.push(&fwd);
        args.push(&bwd);
        args.push(&lr_lit);
        let result = self.trainstep.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        let n = state.n_tensors();
        anyhow::ensure!(outs.len() == 2 * n + 2, "trainstep arity {}", outs.len());
        let n_correct = outs.pop().unwrap().to_vec::<f32>()?[0];
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0];
        let momentum = outs.split_off(n);
        state.params = outs;
        state.momentum = momentum;
        Ok(StepOut { loss, n_correct })
    }

    /// Forward-only pass: loss + correct count (all-subnets mask unless a
    /// partial fwd mask is given — the timed `p_o` program of Table IV).
    pub fn eval(
        &self,
        state: &TrainState,
        x: &xla::Literal,
        y: &xla::Literal,
        fwd_mask: Option<&Tensor>,
    ) -> Result<EvalOut> {
        let cfg = &self.manifest.config;
        let ones = Tensor::full(&[cfg.depth, cfg.heads], 1.0);
        let fwd = Self::mask_literal(fwd_mask.unwrap_or(&ones))?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(state.n_tensors() + 3);
        args.extend(state.params.iter());
        args.push(x);
        args.push(y);
        args.push(&fwd);
        let result = self.eval.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (loss, n_correct) = result.to_tuple2()?;
        Ok(EvalOut {
            loss: loss.to_vec::<f32>()?[0],
            n_correct: n_correct.to_vec::<f32>()?[0],
        })
    }

    /// Contribution-score probe: `[L, H, 4]` (fisher, grad-mag, taylor,
    /// weight-mag) for one micro-batch, without updating weights.
    pub fn probe_scores(
        &self,
        state: &TrainState,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<Tensor> {
        if self.scores.borrow().is_none() {
            let file = self.manifest.artifact("scores")?;
            *self.scores.borrow_mut() = Some(self.registry.executable(file)?);
        }
        let scores_ref = self.scores.borrow();
        let exe = scores_ref.as_ref().unwrap();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(state.n_tensors() + 2);
        args.extend(state.params.iter());
        args.push(x);
        args.push(y);
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let cfg = &self.manifest.config;
        let v = out.to_vec::<f32>()?;
        Ok(Tensor::from_vec(&[cfg.depth, cfg.heads, 4], v))
    }
}
