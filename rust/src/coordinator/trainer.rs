//! The end-to-end fine-tuning driver, generic over the compute backend.

use std::time::Instant;

use anyhow::Result;

use crate::backend::{Backend, BackendProvider, BackendSel, StepOut};
use crate::cluster::{
    CostModel, Engine, EngineConfig, ExecMode, ExecTimeModel, HeteroSpec, WorkloadTracker,
};
use crate::data::{Dataset, DatasetSpec, SyntheticKind};
use crate::metrics::{DeviceUsage, Meter};
use crate::partition::Partition;
use crate::runtime::ModelConfig;
use crate::schedule::scaler::{Lambda, ScalerSched};
use crate::schedule::{
    bilevel::{BiLevel, MergeMode},
    dpruning::DPruning,
    moe_gshard::MoeGshard,
    random_sched::RandomSched,
    Budget, ScheduleTable, Scheduler,
};
use crate::scores::{ScoreBook, ScoreConfig};
use crate::tensor::Tensor;

/// Which scheduling policy to train with (paper baselines + ours).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SchedulerKind {
    /// D2FT bi-level knapsack (exclusive merge — exact per-device counts).
    D2ft,
    /// D2FT with Algorithm 1's verbatim merge (conflicts -> p_f).
    D2ftPaperMerge,
    /// Standard full fine-tuning (everything p_f; ignores the budget).
    Standard,
    /// Budget-matched random operation assignment (§III-A).
    Random,
    /// Dynamic pruning, weight-magnitude score (§III-A).
    DPruningM,
    /// Dynamic pruning, magnitude x gradient score (§III-A).
    DPruningMG,
    /// MoE GShard gating baseline (§III-A).
    MoeGshard,
    /// Single-level "Scaler" knapsack baseline (§IV-F).
    Scaler(Lambda),
}

impl SchedulerKind {
    /// Parse a CLI scheduler label (see `repro train --help` for the
    /// accepted set); round-tripped by `tests/engine.rs`.
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "d2ft" => SchedulerKind::D2ft,
            "d2ft-paper-merge" => SchedulerKind::D2ftPaperMerge,
            "standard" => SchedulerKind::Standard,
            "random" => SchedulerKind::Random,
            "dpruning-m" => SchedulerKind::DPruningM,
            "dpruning-mg" => SchedulerKind::DPruningMG,
            "moe" | "moe-gshard" => SchedulerKind::MoeGshard,
            "scaler-max" => SchedulerKind::Scaler(Lambda::Max),
            "scaler-min" => SchedulerKind::Scaler(Lambda::Min),
            "scaler-0.1" => SchedulerKind::Scaler(Lambda::Const(0.1)),
            "scaler-0.2" => SchedulerKind::Scaler(Lambda::Const(0.2)),
            _ => anyhow::bail!(
                "unknown scheduler {s:?} (d2ft|standard|random|dpruning-m|dpruning-mg|moe|scaler-*)"
            ),
        })
    }

    /// The paper's display label for this policy.
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::D2ft => "D2FT (Ours)",
            SchedulerKind::D2ftPaperMerge => "D2FT (paper merge)",
            SchedulerKind::Standard => "Standard",
            SchedulerKind::Random => "Random",
            SchedulerKind::DPruningM => "DPruning M",
            SchedulerKind::DPruningMG => "DPruning M/G",
            SchedulerKind::MoeGshard => "MoE Gshard",
            SchedulerKind::Scaler(_) => "Scaler",
        }
    }

    /// The CLI token for this policy — the inverse of
    /// [`SchedulerKind::parse`], used when a config is serialized back
    /// out (e.g. a `JobSpec` travelling to the serve control plane).
    pub fn cli_label(&self) -> String {
        match self {
            SchedulerKind::D2ft => "d2ft".to_string(),
            SchedulerKind::D2ftPaperMerge => "d2ft-paper-merge".to_string(),
            SchedulerKind::Standard => "standard".to_string(),
            SchedulerKind::Random => "random".to_string(),
            SchedulerKind::DPruningM => "dpruning-m".to_string(),
            SchedulerKind::DPruningMG => "dpruning-mg".to_string(),
            SchedulerKind::MoeGshard => "moe".to_string(),
            SchedulerKind::Scaler(Lambda::Max) => "scaler-max".to_string(),
            SchedulerKind::Scaler(Lambda::Min) => "scaler-min".to_string(),
            SchedulerKind::Scaler(Lambda::Const(c)) => format!("scaler-{c}"),
        }
    }
}

/// How parameter updates are applied within one scheduled batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateMode {
    /// One fused SGD-momentum update per micro-batch, sequentially —
    /// the seed trainer's semantics (micro-batch `i+1` sees the weights
    /// micro-batch `i` produced).
    PerMicro,
    /// Accumulate the batch's micro-batch gradients (fixed micro order),
    /// take the mean, and apply a single fused update — synchronous
    /// data-parallel semantics. This is the serial reference the
    /// [`crate::dist`] runtime reproduces bitwise: every micro-batch
    /// gradient is computed against the same parameter snapshot, so the
    /// computation can be sharded across workers without changing a bit.
    BatchAccum,
}

impl UpdateMode {
    /// Display label (`per-micro` / `batch-accum`).
    pub fn label(&self) -> &'static str {
        match self {
            UpdateMode::PerMicro => "per-micro",
            UpdateMode::BatchAccum => "batch-accum",
        }
    }
}

/// Full configuration of one fine-tuning run.
///
/// `#[non_exhaustive]`: construct via [`TrainerConfig::builder`] (or
/// the [`TrainerConfig::quick`] shorthand) — fields stay pub for
/// reading and targeted mutation, but the struct-literal form is
/// reserved to the builder module so defaults and validation live in
/// one place ([`crate::config`]).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct TrainerConfig {
    /// Which synthetic dataset preset to fine-tune on.
    pub dataset: SyntheticKind,
    /// Training examples to generate.
    pub train_size: usize,
    /// Test examples to generate.
    pub test_size: usize,
    /// Micro-batches per batch (paper: 5).
    pub micros_per_batch: usize,
    /// Number of fine-tuning batches to run.
    pub batches: usize,
    /// SGD-momentum learning rate.
    pub lr: f32,
    /// Per-device operation budget.
    pub budget: Budget,
    /// Scheduling policy (D2FT or a baseline).
    pub scheduler: SchedulerKind,
    /// Which contribution metrics feed the bi-level knapsack.
    pub scores: ScoreConfig,
    /// How the simulated cluster executes each scheduled batch:
    /// parallel workers (the engine) or the serial reference path.
    /// Deterministic metrics are identical either way.
    pub exec: ExecMode,
    /// Head-group size for the partition (1 = per-head; Table V).
    pub partition_group: usize,
    /// Device heterogeneity configuration (None = homogeneous).
    pub hetero: Option<HeteroSpec>,
    /// Run seed (data order, random baselines, engine payloads, native
    /// parameter init).
    pub seed: u64,
    /// Batches of synthetic "pre-training" before fine-tuning
    /// (DESIGN.md Substitution 4; gives non-degenerate scores).
    pub pretrain_batches: usize,
    /// Evaluate on the test split every `eval_every` batches (0 = only
    /// at the end).
    pub eval_every: usize,
    /// LoRA adapter rank the backend should open (0 = full fine-tuning).
    pub lora_rank: usize,
    /// Open the backend at this micro-batch-size *variant* trainstep
    /// (Table VI) instead of the provider default. Set via the
    /// builder's `micro_batch` knob — this absorbed the old
    /// `Trainer::new_with_micro_batch` entry point.
    pub micro_batch: Option<usize>,
    /// Whether updates apply per micro-batch (sequential, the seed
    /// semantics) or once per batch from accumulated gradients (the
    /// data-parallel semantics `dist::DistTrainer` distributes).
    pub update: UpdateMode,
}

impl TrainerConfig {
    /// Builder seeded with the quick-run defaults; every construction
    /// site goes through it (see [`crate::config`]).
    pub fn builder() -> crate::config::TrainerConfigBuilder {
        crate::config::TrainerConfigBuilder::new()
    }

    /// Short-run defaults used by the experiments and tests.
    pub fn quick(dataset: SyntheticKind, scheduler: SchedulerKind, budget: Budget) -> Self {
        TrainerConfig::builder()
            .dataset(dataset)
            .scheduler(scheduler)
            .budget(budget)
            .build()
            .expect("quick-run defaults always validate")
    }
}

/// Everything an experiment needs to print a paper row.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Display label of the scheduling policy.
    pub scheduler: String,
    /// Display label of the compute backend that ran the numerics.
    pub backend: String,
    /// Mean training loss over the run.
    pub final_train_loss: f64,
    /// Test top-1 accuracy after the run.
    pub test_top1: f64,
    /// Test loss after the run.
    pub test_loss: f64,
    /// Per-micro-batch training losses in execution order.
    pub loss_curve: Vec<f32>,
    /// `(batch, top-1)` samples when `eval_every > 0`.
    pub eval_curve: Vec<(usize, f64)>,
    /// Compute cost relative to standard fine-tuning.
    pub compute_fraction: f64,
    /// Communication cost relative to standard fine-tuning.
    pub comm_fraction: f64,
    /// Variance of per-device compute fraction (Table I).
    pub workload_variance: f64,
    /// Variance of per-device processed micro-batch counts.
    pub sample_count_variance: f64,
    /// Modelled mean per-device execution time per batch (ms).
    pub mean_exec_ms: f64,
    /// Modelled batch makespan (slowest device, ms).
    pub makespan_ms: f64,
    /// Cluster execution mode label (`serial` / `parallel(...)`).
    pub engine: String,
    /// Mean per-device utilization across the run (engine-observed).
    pub utilization: f64,
    /// Straggler busy time over mean busy time, minus one (0 = balanced).
    pub imbalance: f64,
    /// Measured mean straggler (slowest worker) wall time per batch
    /// (ms). The trainer runs the engine at its *accounting* operating
    /// point (no simulated spinning), so this measures the real
    /// dispatch/bookkeeping cost of the slowest worker — the full
    /// simulation point, where devices spin for their modeled time, is
    /// exercised by `benches/engine_parallel.rs` and `tests/engine.rs`.
    pub straggler_ms: f64,
    /// Measured wall-clock of the fine-tuning loop (s).
    pub wall_s: f64,
    /// Batches actually executed.
    pub batches: usize,
    /// Overall cumulative rescale of the modeled exec-time tables from
    /// measured times — the geometric mean of the per-op factors below
    /// (`dist` calibration loop; 1.0 = the paper's uncalibrated V100
    /// table, which the serial trainer always uses).
    pub calib_scale: f64,
    /// Cumulative rescale of the `p_f` (full fwd+bwd) time table. The
    /// dist calibration solves `p_f` and `p_o` factors separately from
    /// measured per-task times ([`crate::cluster::OpCalibrator`]), so a
    /// host whose fwd/full cost ratio differs from the paper's V100 is
    /// tracked per op instead of averaged away.
    pub calib_scale_full: f64,
    /// Cumulative rescale of the `p_o` (forward-only) time table.
    pub calib_scale_fwd: f64,
    /// Epoch-boundary calibrations performed (0 = never calibrated).
    pub calib_epochs: usize,
    /// Mean modeled-vs-measured makespan drift
    /// (`|modeled - measured| / measured`, per-epoch means) over the
    /// epochs *after* the first calibration; 0.0 when no calibrated
    /// epoch completed. The dist bench asserts this stays <= 20%.
    pub makespan_drift: f64,
}

pub(crate) fn build_scheduler(
    kind: SchedulerKind,
    scores: ScoreConfig,
    seed: u64,
) -> Box<dyn Scheduler> {
    let cost = CostModel::paper();
    match kind {
        SchedulerKind::D2ft => Box::new(BiLevel::new(scores, cost)),
        SchedulerKind::D2ftPaperMerge => {
            Box::new(BiLevel::new(scores, cost).with_merge(MergeMode::PaperMerge))
        }
        SchedulerKind::Standard => Box::new(StandardSched),
        SchedulerKind::Random => Box::new(RandomSched::new(seed ^ 0xAB)),
        SchedulerKind::DPruningM => Box::new(DPruning::magnitude()),
        SchedulerKind::DPruningMG => Box::new(DPruning::magnitude_gradient()),
        SchedulerKind::MoeGshard => Box::new(MoeGshardHolder { inner: None, seed }),
        SchedulerKind::Scaler(l) => Box::new(ScalerSched::new(l, scores, cost)),
    }
}

/// Standard fine-tuning as a Scheduler (everything p_f).
struct StandardSched;

impl Scheduler for StandardSched {
    fn name(&self) -> &'static str {
        "Standard"
    }

    fn schedule(&mut self, scores: &ScoreBook, _budget: &Budget) -> ScheduleTable {
        ScheduleTable::standard(scores.n_subnets, scores.n_micro)
    }

    fn needs_scores(&self) -> bool {
        false
    }
}

/// MoeGshard needs subnets-per-block, only known at schedule time.
struct MoeGshardHolder {
    inner: Option<MoeGshard>,
    seed: u64,
}

impl Scheduler for MoeGshardHolder {
    fn name(&self) -> &'static str {
        "MoE Gshard"
    }

    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable {
        let spb = crate::coordinator::trainer::SPB_HINT
            .with(|h| h.get())
            .max(1);
        let inner = self
            .inner
            .get_or_insert_with(|| MoeGshard::new(self.seed ^ 0xCD, spb));
        inner.schedule(scores, budget)
    }
}

thread_local! {
    /// Subnets-per-block hint for schedulers that need block structure.
    pub(crate) static SPB_HINT: std::cell::Cell<usize> = const { std::cell::Cell::new(1) };
}

/// Partition + datasets for one run configuration.
pub(crate) struct RunSetup {
    pub(crate) partition: Partition,
    pub(crate) train: Dataset,
    pub(crate) test: Dataset,
}

/// Resolve the model partition, validate it, publish the
/// subnets-per-block hint, and generate the train/test splits — shared
/// by the serial [`Trainer`] and `dist::DistTrainer` so the two drivers
/// cannot drift (their bitwise-equality contract depends on identical
/// setup).
pub(crate) fn prepare_run(mc: &ModelConfig, cfg: &TrainerConfig) -> Result<RunSetup> {
    let partition = match &cfg.hetero {
        Some(h) => h.partition(mc),
        None => Partition::grouped(mc, cfg.partition_group),
    };
    partition.validate()?;
    SPB_HINT.with(|h| h.set(partition.n_subnets() / mc.depth));
    let train = DatasetSpec::preset(cfg.dataset, mc.img_size, cfg.train_size, cfg.seed)
        .generate("train");
    let test = DatasetSpec::preset(cfg.dataset, mc.img_size, cfg.test_size, cfg.seed)
        .generate("test");
    anyhow::ensure!(
        train.classes <= mc.classes,
        "dataset has more classes than the model head"
    );
    Ok(RunSetup { partition, train, test })
}

/// Execute one batch of micro-steps under per-micro mask pairs, honoring
/// the [`UpdateMode`]. Returns the per-micro step stats in micro order.
///
/// In [`UpdateMode::BatchAccum`], gradients are summed densely in
/// ascending micro order (starting from explicit zeros), scaled by
/// `1/n`, and applied in one fused update — the exact arithmetic
/// sequence [`crate::dist`]'s `DistTrainer` reproduces from decoded wire
/// messages, which is what makes serial ≡ distributed a *bitwise*
/// statement rather than an approximate one.
fn run_batch<'b>(
    backend: &mut (dyn Backend + 'b),
    update: UpdateMode,
    lr: f32,
    micros: &[(Tensor, Vec<i32>)],
    masks: &[crate::schedule::MaskPair],
) -> Result<Vec<StepOut>> {
    assert_eq!(micros.len(), masks.len(), "one mask pair per micro-batch");
    let mut outs = Vec::with_capacity(micros.len());
    match update {
        UpdateMode::PerMicro => {
            for ((x, y), m) in micros.iter().zip(masks) {
                outs.push(backend.step(x, y, m, lr)?);
            }
        }
        UpdateMode::BatchAccum => {
            let mut acc: Vec<Tensor> = Vec::new();
            for ((x, y), m) in micros.iter().zip(masks) {
                let (out, grads) = backend.grad_step(x, y, m)?;
                if acc.is_empty() {
                    acc = grads.iter().map(|g| Tensor::zeros(g.shape())).collect();
                }
                for (a, g) in acc.iter_mut().zip(&grads) {
                    a.add_assign(g);
                }
                outs.push(out);
            }
            let scale = 1.0 / micros.len() as f32;
            for a in &mut acc {
                a.scale(scale);
            }
            backend.apply_grads(&acc, lr)?;
        }
    }
    Ok(outs)
}

/// The coordinator: drives any [`Backend`] through the full
/// pretrain -> score -> schedule -> execute loop.
pub struct Trainer<'a> {
    cfg: TrainerConfig,
    backend: Box<dyn Backend + 'a>,
    partition: Partition,
    train: Dataset,
    test: Dataset,
}

impl<'a> Trainer<'a> {
    /// Build a trainer over a backend opened from `provider` (LoRA rank
    /// and seed from the config), partition the model, and generate the
    /// train/test splits.
    pub fn new(provider: &'a dyn BackendProvider, cfg: TrainerConfig) -> Result<Trainer<'a>> {
        let sel = BackendSel {
            lora_rank: cfg.lora_rank,
            micro_batch: cfg.micro_batch,
            seed: cfg.seed,
        };
        Self::with_backend(provider.open(&sel)?, cfg)
    }

    /// Build a trainer around an already-opened backend.
    pub fn with_backend(backend: Box<dyn Backend + 'a>, cfg: TrainerConfig) -> Result<Trainer<'a>> {
        let setup = prepare_run(backend.config(), &cfg)?;
        Ok(Trainer {
            cfg,
            backend,
            partition: setup.partition,
            train: setup.train,
            test: setup.test,
        })
    }

    /// Micro-batch size of the *training* step (variant-aware).
    fn mb(&self) -> usize {
        self.backend.micro_batch()
    }

    /// The backend this trainer drives.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// The model partition this run schedules over.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Synthetic pre-training: standard schedule on the broad
    /// distribution so fine-tuning starts from informative weights.
    fn pretrain(&mut self) -> Result<()> {
        if self.cfg.pretrain_batches == 0 {
            return Ok(());
        }
        let (img, depth, heads) = {
            let mc = self.backend.config();
            (mc.img_size, mc.depth, mc.heads)
        };
        let mb = self.mb();
        let n = self.cfg.pretrain_batches * self.cfg.micros_per_batch * mb;
        let pre = DatasetSpec::preset(SyntheticKind::Pretrain, img, n, self.cfg.seed ^ 0x5A)
            .generate("train");
        let mut batcher =
            crate::data::Batcher::new(&pre, mb, self.cfg.micros_per_batch, self.cfg.seed);
        while let Some(micros) = batcher.next_batch() {
            let masks: Vec<crate::schedule::MaskPair> = (0..micros.len())
                .map(|_| crate::schedule::MaskPair::ones(depth, heads))
                .collect();
            run_batch(
                self.backend.as_mut(),
                self.cfg.update,
                self.cfg.lr,
                &micros,
                &masks,
            )?;
        }
        // Fresh optimizer state at the pretrain -> fine-tune boundary
        // (momentum from the broad distribution destabilizes the first
        // fine-tuning steps otherwise).
        self.backend.reset_momentum()?;
        Ok(())
    }

    /// Evaluate test top-1 (full forward, all parameters — §III-A).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mb = self.backend.eval_micro_batch();
        let mut meter = Meter::new();
        let mut i = 0;
        while i + mb <= self.test.len() {
            let idxs: Vec<usize> = (i..i + mb).collect();
            let (x, y) = self.test.gather(&idxs);
            let out = self.backend.eval(&x, &y, None)?;
            meter.push(out.loss, out.n_correct, mb);
            i += mb;
        }
        Ok((meter.top1(), meter.mean_loss()))
    }

    /// Run the full fine-tuning loop and report paper metrics.
    pub fn run(&mut self) -> Result<TrainReport> {
        let mb = self.mb();
        if self.cfg.update == UpdateMode::BatchAccum {
            anyhow::ensure!(
                self.backend.supports_grad_exchange(),
                "batch-accum updates need a gradient-exchange backend \
                 ({} cannot export gradients; use the native backend)",
                self.backend.label()
            );
        }
        self.pretrain()?;

        let mut scheduler = build_scheduler(self.cfg.scheduler, self.cfg.scores, self.cfg.seed);
        let budget = match &self.cfg.hetero {
            Some(h) => h.budget(self.cfg.budget.clone(), self.partition.n_subnets()),
            None => self.cfg.budget.clone(),
        };
        let cost = CostModel::paper();
        let n_devices = self.partition.n_subnets();
        let mut workloads = WorkloadTracker::new(cost, n_devices);
        // The simulated cluster: parallel worker threads (or the serial
        // reference path) execute each scheduled batch and report per-
        // device modeled + measured times through the step barrier.
        let mut engine = Engine::with_models(
            EngineConfig::accounting(self.cfg.exec, self.cfg.seed),
            n_devices,
            ExecTimeModel::paper(),
            cost,
        );
        let mut usage = DeviceUsage::new(n_devices);
        let mut loss_curve = Vec::with_capacity(self.cfg.batches);
        let mut eval_curve = Vec::new();
        let mut score_cache: Vec<Option<ScoreBook>> = Vec::new();
        let mut exec_ms_sum = 0.0;
        let mut makespan_sum = 0.0;
        let mut meter = Meter::new();

        let t0 = Instant::now();
        let mut batch_idx = 0;
        'outer: while batch_idx < self.cfg.batches {
            let mut batcher = crate::data::Batcher::new(
                &self.train,
                mb,
                self.cfg.micros_per_batch,
                self.cfg.seed, // same order every epoch -> score cache valid
            );
            let mut epoch_pos = 0usize;
            while let Some(micros) = batcher.next_batch() {
                if batch_idx >= self.cfg.batches {
                    break 'outer;
                }
                // --- contribution scores (cached; paper computes them
                // once before fine-tuning). Kept in lockstep with
                // dist::DistTrainer's score-cache block — the bitwise
                // serial ≡ dist contract depends on it. -------------------
                if score_cache.len() <= epoch_pos {
                    score_cache.resize(epoch_pos + 1, None);
                }
                if score_cache[epoch_pos].is_none() {
                    let can_probe = self.backend.supports_probe();
                    score_cache[epoch_pos] = Some(if scheduler.needs_scores() && can_probe {
                        let probes: Vec<Tensor> = micros
                            .iter()
                            .map(|(x, y)| self.backend.score_probe(x, y))
                            .collect::<Result<_>>()?;
                        ScoreBook::from_probes(&self.partition, &probes)
                    } else {
                        // Score-free policies (Standard, Random) skip the
                        // probe entirely — it never runs (and on the XLA
                        // backend its artifact never compiles).
                        ScoreBook::zeros(self.partition.n_subnets(), micros.len())
                    });
                }
                let book = score_cache[epoch_pos].as_ref().unwrap();
                // --- schedule + execute -----------------------------------
                let table = scheduler.schedule(book, &budget);
                let masks: Vec<crate::schedule::MaskPair> = (0..micros.len())
                    .map(|i| table.masks_for_micro(&self.partition, i))
                    .collect();
                let outs = run_batch(
                    self.backend.as_mut(),
                    self.cfg.update,
                    self.cfg.lr,
                    &micros,
                    &masks,
                )?;
                for out in outs {
                    meter.push(out.loss, out.n_correct, mb);
                    loss_curve.push(out.loss);
                }
                // --- simulated cluster execution ---------------------------
                let cluster = engine.execute(&table);
                workloads.record(&table);
                workloads.record_measured(&cluster.measured_ms());
                usage.record(&cluster.finish_ms());
                exec_ms_sum += cluster.mean_device_ms;
                makespan_sum += cluster.makespan_ms;
                if self.cfg.eval_every > 0 && (batch_idx + 1) % self.cfg.eval_every == 0 {
                    let (top1, _) = self.evaluate()?;
                    eval_curve.push((batch_idx + 1, top1));
                }
                batch_idx += 1;
                epoch_pos += 1;
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let (test_top1, test_loss) = self.evaluate()?;
        let b = workloads.batches().max(1) as f64;
        Ok(TrainReport {
            scheduler: self.cfg.scheduler.label().to_string(),
            backend: self.backend.label().to_string(),
            final_train_loss: meter.mean_loss(),
            test_top1,
            test_loss,
            loss_curve,
            eval_curve,
            compute_fraction: workloads.total_compute_fraction(),
            comm_fraction: workloads.total_comm_fraction(),
            workload_variance: workloads.workload_variance(),
            sample_count_variance: workloads.sample_count_variance(),
            mean_exec_ms: exec_ms_sum / b,
            makespan_ms: makespan_sum / b,
            engine: self.cfg.exec.label(),
            utilization: usage.mean_utilization(),
            imbalance: usage.imbalance(),
            straggler_ms: workloads.straggler_ms() / b,
            wall_s,
            batches: batch_idx,
            // The serial reference never recalibrates: it is the
            // uncalibrated baseline the dist runtime's measured loop is
            // compared against.
            calib_scale: 1.0,
            calib_scale_full: 1.0,
            calib_scale_fwd: 1.0,
            calib_epochs: 0,
            makespan_drift: 0.0,
        })
    }
}
