//! Training coordinator: the L3 driver that ties partitioning, scoring,
//! scheduling, the simulated cluster, and the PJRT runtime into the
//! fine-tuning loop.
//!
//! Per batch: (1) fetch 5 micro-batches, (2) probe contribution scores
//! (cached per batch index — the paper computes scores once before
//! fine-tuning, §II-A3), (3) run the scheduler, (4) execute each
//! micro-batch's fused trainstep under its mask pair, (5) charge the
//! simulated cluster. Python never runs here.

mod trainer;

pub use trainer::{SchedulerKind, Trainer, TrainerConfig, TrainReport, UpdateMode};
#[cfg(feature = "native")]
pub(crate) use trainer::{build_scheduler, prepare_run};
