//! `repro` — the D2FT leader binary.
//!
//! Subcommands:
//!   repro train       [flags]   one fine-tuning run, any scheduler
//!   repro dist-worker --connect host:port   join a TCP dist cluster
//!   repro experiment  <id>      regenerate a paper table/figure
//!   repro list                  list experiments
//!   repro info                  backend/model summary
//!
//! `--backend native` (the default) needs no setup at all; `--backend
//! xla` needs a build with `--features xla` plus `make artifacts`.
//! `repro train --dist --workers K` runs the real data-parallel trainer
//! (K worker replicas, masked-gradient exchange, measured bytes).
//! `--transport tcp` moves the exchange onto real sockets: the
//! aggregator listens and forks K `repro dist-worker` subprocesses, or
//! — with `--no-spawn` — waits for workers launched by hand (on this
//! machine or any other) via `repro dist-worker --connect host:port`.
//! Numerics are bitwise identical across transports.

use anyhow::Result;

use d2ft::backend::{provider_for, BackendKind, BackendProvider};
use d2ft::cluster::ExecMode;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::experiments::{list_experiments, run_experiment, ExperimentCtx};
use d2ft::metrics::{fmt_bytes, pct};
use d2ft::schedule::Budget;
use d2ft::scores::{Metric, ScoreConfig};
use d2ft::util::cli::Cli;

fn cli() -> Cli {
    Cli::new("repro", "D2FT: Distributed Dynamic Fine-Tuning (paper reproduction)")
        .positional("command", "train | dist-worker | experiment <id> | list | info")
        .positional("experiment-id", "experiment id for `experiment`")
        .flag(
            "backend",
            "native",
            "compute backend: native (pure Rust, zero setup) | xla (PJRT artifacts)",
        )
        .flag("model", "mini", "native model preset: mini | small (ViT-small-like, 74 subnets)")
        .flag("artifacts", "artifacts", "artifacts directory (xla backend only; make artifacts)")
        .flag("dataset", "c100", "c10 | c100 | cars")
        .flag(
            "scheduler",
            "d2ft",
            "d2ft | standard | random | dpruning-m | dpruning-mg | moe | scaler-max|min|0.1|0.2",
        )
        .flag("batches", "30", "fine-tuning batches")
        .flag("pretrain-batches", "10", "synthetic pre-training batches")
        .flag("train-size", "480", "training examples")
        .flag("test-size", "160", "test examples")
        .flag("micros", "5", "micro-batches per batch")
        .flag("n-full", "3", "p_f micro-batches per device per batch")
        .flag("n-fwd", "1", "p_o micro-batches per device per batch")
        .flag("lr", "0.03", "SGD learning rate")
        .flag("seed", "17", "run seed")
        .flag("backward-score", "weightmag", "fisher|gradmag|taylor|weightmag")
        .flag("forward-score", "fisher", "fisher|gradmag|taylor|weightmag")
        .flag("partition-group", "1", "heads per subnet (Table V)")
        .flag("scale", "1.0", "experiment run-length scale factor")
        .flag("lora-rank", "0", "LoRA adapter rank (0 = full FT)")
        .flag("eval-every", "0", "evaluate test top-1 every N batches")
        .flag(
            "workers",
            "0",
            "engine worker threads (0 = one per simulated device; with --dist: 0 = 4 replicas)",
        )
        .flag(
            "exchange",
            "allreduce",
            "dist gradient exchange: allreduce | ps (parameter server) | ring | hier \
             (two-level ring through group leaders)",
        )
        .flag(
            "compress",
            "none",
            "dist gradient wire compression: none | int8 | int4 (quantized, error feedback) | \
             topk[:PCT] (top-k sparsification)",
        )
        .flag(
            "ring-group",
            "0",
            "hier exchange: workers per group (0 = ceil(sqrt(K)))",
        )
        .flag(
            "threads",
            "1",
            "matmul kernel threads (native backend; 1 = serial default, 0 = auto/per-core)",
        )
        .flag(
            "wire",
            "f32",
            "dist gradient wire precision: f32 (lossless) | f16 (half the bytes, lossy)",
        )
        .flag(
            "transport",
            "channel",
            "dist frame transport: channel (in-process) | tcp (worker processes over sockets)",
        )
        .flag(
            "listen",
            "127.0.0.1:0",
            "tcp transport: aggregator bind address (port 0 = ephemeral)",
        )
        .flag("connect", "", "dist-worker: aggregator address to join (host:port)")
        .flag(
            "fault",
            "",
            "scripted faults: train --dist takes `W:PLAN,...`, dist-worker takes `PLAN` \
             (PLAN = kill-after-micro=N | stall-ms=M@N | drop-uplink=N | rejoin-at-epoch=E | \
             reset-after-frame=N | corrupt-frame=N | delay-ms=M@N | partition-ms=M@E, \
             ';'-joined; the last four act at the network layer)",
        )
        .flag("heartbeat-ms", "500", "dist worker heartbeat interval in ms (0 = disabled)")
        .flag("liveness-misses", "4", "missed heartbeats before a dist worker is declared lost")
        .flag("report-json", "", "train --dist: write the DistReport as JSON to this path")
        .flag("checkpoint-dir", "", "train --dist: write epoch-boundary checkpoints here")
        .flag(
            "checkpoint-retain",
            "2",
            "train --dist: epoch checkpoints kept after rotation (older ones are deleted)",
        )
        .flag(
            "resume",
            "",
            "train --dist: resume from a checkpoint file, or from a checkpoint *directory* \
             (crash recovery: picks the newest loadable checkpoint + the progress record); \
             skips pre-training",
        )
        .flag(
            "halt-after-batch",
            "",
            "train --dist: crash simulation — exit abruptly right after completing this many \
             batches (progress record on disk, no shutdown handshake); pair with --resume",
        )
        .flag(
            "trace-out",
            "",
            "train --dist: write a merged Chrome trace-event JSON here (open in Perfetto; \
             one lane per worker plus the aggregator)",
        )
        .flag(
            "metrics-addr",
            "",
            "train --dist: serve live Prometheus metrics on this address \
             (e.g. 127.0.0.1:9464; /metrics text + /json dump)",
        )
        .switch(
            "no-spawn",
            "tcp transport: do not fork dist-worker subprocesses; wait for external workers",
        )
        .switch("serial", "serial cluster execution (reference path; same metrics)")
        .switch(
            "dist",
            "real data-parallel training: worker replicas + masked-gradient exchange (native)",
        )
        .switch(
            "no-overlap",
            "serialize each dist worker's encode+upload after its compute (default overlaps)",
        )
        .switch(
            "no-calibrate",
            "keep the paper's V100 exec-time model instead of recalibrating from measured times",
        )
        .switch(
            "batch-accum",
            "one aggregated update per batch (the dist semantics) instead of per-micro",
        )
        .switch("quiet", "suppress info logging")
}

fn main() -> Result<()> {
    d2ft::util::log::init();
    let args = match cli().parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.get_bool("quiet") {
        d2ft::util::log::set_level(d2ft::util::log::Level::Warn);
    }
    let open_provider = || -> Result<Box<dyn BackendProvider>> {
        let kind = BackendKind::parse(args.get("backend"))?;
        let model = args.get("model");
        match kind {
            #[cfg(feature = "native")]
            BackendKind::Native => {
                let mut spec = d2ft::backend::native::NativeSpec::preset(model)?;
                spec.threads = args.get_usize("threads")?;
                Ok(Box::new(d2ft::backend::native::NativeProvider::new(spec)))
            }
            _ => {
                anyhow::ensure!(
                    matches!(model.to_ascii_lowercase().as_str(), "mini" | "tiny"),
                    "--model presets apply to the native backend only"
                );
                provider_for(kind, std::path::Path::new(args.get("artifacts")))
            }
        }
    };
    let command = args.positional(0).unwrap_or("info").to_string();
    match command.as_str() {
        "list" => {
            for (id, desc) in list_experiments() {
                println!("{id:<10} {desc}");
            }
            Ok(())
        }
        "info" => {
            let provider = open_provider()?;
            let m = provider.model_config();
            println!("backend         {}", provider.label());
            println!(
                "model           ViT d{} x{}L x{}H, {}x{} px, {} classes",
                m.dim, m.depth, m.heads, m.img_size, m.img_size, m.classes
            );
            println!(
                "micro-batch     {} (variants {:?})",
                provider.micro_batch(),
                provider.mb_variants()
            );
            println!(
                "parameters      {} tensors, {} elems",
                provider.n_params(),
                provider.total_elems()
            );
            println!(
                "lora ranks      {:?} (standard {})",
                provider.lora_ranks(),
                provider.lora_standard_rank()
            );
            println!(
                "body subnets    {} (+2 = {} devices)",
                m.body_subnets(),
                m.body_subnets() + 2
            );
            Ok(())
        }
        "dist-worker" => run_dist_worker(&args),
        "experiment" => {
            let id = args
                .positional(1)
                .ok_or_else(|| anyhow::anyhow!("usage: repro experiment <id> (see `repro list`)"))?
                .to_string();
            let provider = open_provider()?;
            let mut ctx = ExperimentCtx::new(provider.as_ref());
            ctx.scale = args.get_f64("scale")?;
            ctx.seed = args.get_u64("seed")?;
            run_experiment(&ctx, &id)?;
            Ok(())
        }
        "train" => {
            let micros = args.get_usize("micros")?;
            let budget = Budget::uniform(
                micros,
                args.get_usize("n-full")?,
                args.get_usize("n-fwd")?,
            );
            let cfg = TrainerConfig {
                dataset: SyntheticKind::parse(args.get("dataset"))?,
                train_size: args.get_usize("train-size")?,
                test_size: args.get_usize("test-size")?,
                micros_per_batch: micros,
                batches: args.get_usize("batches")?,
                lr: args.get_f32("lr")?,
                budget,
                scheduler: SchedulerKind::parse(args.get("scheduler"))?,
                scores: ScoreConfig {
                    backward: Metric::parse(args.get("backward-score"))?,
                    forward: Metric::parse(args.get("forward-score"))?,
                },
                exec: if args.get_bool("serial") {
                    ExecMode::Serial
                } else {
                    ExecMode::Parallel { workers: args.get_usize("workers")? }
                },
                partition_group: args.get_usize("partition-group")?,
                hetero: None,
                seed: args.get_u64("seed")?,
                pretrain_batches: args.get_usize("pretrain-batches")?,
                eval_every: args.get_usize("eval-every")?,
                lora_rank: args.get_usize("lora-rank")?,
                update: if args.get_bool("batch-accum") || args.get_bool("dist") {
                    UpdateMode::BatchAccum
                } else {
                    UpdateMode::PerMicro
                },
            };
            if args.get_bool("dist") {
                return run_dist(&args, cfg);
            }
            let provider = open_provider()?;
            let mut trainer = Trainer::new(provider.as_ref(), cfg)?;
            let r = trainer.run()?;
            println!("backend              {}", r.backend);
            println!("scheduler            {}", r.scheduler);
            println!("batches              {}", r.batches);
            println!("final train loss     {:.4}", r.final_train_loss);
            println!("test top-1           {}", pct(r.test_top1));
            println!("test loss            {:.4}", r.test_loss);
            println!("compute fraction     {}", pct(r.compute_fraction));
            println!("comm fraction        {}", pct(r.comm_fraction));
            println!("workload variance    {:.4}", r.workload_variance);
            println!("mean exec (model)    {:.2}ms", r.mean_exec_ms);
            println!("makespan (model)     {:.2}ms", r.makespan_ms);
            println!("engine               {}", r.engine);
            println!("device utilization   {}", pct(r.utilization));
            println!("imbalance            {:.4}", r.imbalance);
            println!("straggler (measured) {:.3}ms/batch", r.straggler_ms);
            println!("wall time            {:.1}s", r.wall_s);
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", cli().usage());
            std::process::exit(2);
        }
    }
}

/// `repro dist-worker --connect host:port`: join a TCP dist cluster as
/// one worker replica. Model-agnostic: everything (spec, seed, LoRA
/// rank, wire precision) arrives in the aggregator's Init frame, so
/// the same invocation serves any run — including one on another host.
#[cfg(feature = "native")]
fn run_dist_worker(args: &d2ft::util::cli::Args) -> Result<()> {
    use d2ft::dist::{run_worker_reconnecting, BufPool, FaultPlan};
    use std::sync::Arc;

    let addr = args.get("connect");
    anyhow::ensure!(
        !addr.is_empty(),
        "usage: repro dist-worker --connect <host:port> (the aggregator's --listen address)"
    );
    let plan = FaultPlan::parse(args.get("fault"))?;
    let pool = Arc::new(BufPool::new());
    // The redial window lets this worker outlive an aggregator restart:
    // a dropped link is retried with capped backoff until the window
    // expires, so `--resume` on the aggregator side picks the same
    // replica back up instead of spawning a fresh one.
    run_worker_reconnecting(addr, pool, plan, std::time::Duration::from_secs(60))?;
    d2ft::info!("dist-worker shut down cleanly");
    Ok(())
}

#[cfg(not(feature = "native"))]
fn run_dist_worker(_args: &d2ft::util::cli::Args) -> Result<()> {
    anyhow::bail!("dist-worker needs the `native` feature (rebuild with default features)")
}

/// `repro train --dist`: the real data-parallel runtime (native only).
#[cfg(feature = "native")]
fn run_dist(args: &d2ft::util::cli::Args, cfg: TrainerConfig) -> Result<()> {
    use d2ft::backend::native::{NativeProvider, NativeSpec};
    use d2ft::dist::{
        parse_worker_plans, DistConfig, DistTrainer, ExchangeMode, SpawnMode, TransportKind,
    };

    anyhow::ensure!(
        d2ft::backend::BackendKind::parse(args.get("backend"))?
            == d2ft::backend::BackendKind::Native,
        "--dist runs on the native backend (worker replicas need Send numerics)"
    );
    let mut spec = NativeSpec::preset(args.get("model"))?;
    spec.threads = args.get_usize("threads")?;
    let provider = NativeProvider::new(spec);
    let workers = match args.get_usize("workers")? {
        0 => 4,
        w => w,
    };
    let transport = match TransportKind::parse(args.get("transport"))? {
        TransportKind::Tcp { .. } => TransportKind::Tcp {
            listen: args.get("listen").to_string(),
            spawn: if args.get_bool("no-spawn") {
                SpawnMode::External
            } else {
                SpawnMode::Processes
            },
        },
        kind => kind,
    };
    let to_path = |flag: &str| -> Option<std::path::PathBuf> {
        let v = args.get(flag);
        (!v.is_empty()).then(|| std::path::PathBuf::from(v))
    };
    // The registry is shared with the trainer; starting the server
    // before the run means a scrape mid-training sees live values.
    let registry = std::sync::Arc::new(d2ft::obs::Registry::new());
    let metrics_addr = args.get("metrics-addr");
    let _metrics_server = if metrics_addr.is_empty() {
        None
    } else {
        let srv = d2ft::obs::MetricsServer::start(metrics_addr, std::sync::Arc::clone(&registry))?;
        d2ft::info!("serving metrics at http://{}/metrics", srv.addr());
        Some(srv)
    };
    let dcfg = DistConfig {
        exchange: ExchangeMode::parse(args.get("exchange"))?,
        transport,
        overlap: !args.get_bool("no-overlap"),
        wire_precision: d2ft::dist::WirePrecision::parse(args.get("wire"))?,
        compress: d2ft::dist::WireCompression::parse(args.get("compress"))?,
        ring_group: args.get_usize("ring-group")?,
        calibrate: !args.get_bool("no-calibrate"),
        heartbeat_ms: args.get_u64("heartbeat-ms")?,
        liveness_misses: args.get_usize("liveness-misses")? as u32,
        faults: parse_worker_plans(args.get("fault"))?,
        checkpoint_dir: to_path("checkpoint-dir"),
        checkpoint_retain: args.get_usize("checkpoint-retain")?,
        resume_from: to_path("resume"),
        halt_after_batch: {
            let v = args.get("halt-after-batch");
            if v.is_empty() {
                None
            } else {
                Some(v.parse::<usize>().map_err(|e| {
                    anyhow::anyhow!("--halt-after-batch {v:?}: {e} (expected a batch count)")
                })?)
            }
        },
        trace_out: to_path("trace-out"),
        metrics: Some(std::sync::Arc::clone(&registry)),
        ..DistConfig::new(cfg, workers)
    };
    let mut trainer = DistTrainer::new(&provider, dcfg)?;
    let r = trainer.run()?;
    let report_path = args.get("report-json");
    if !report_path.is_empty() {
        std::fs::write(report_path, r.to_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {report_path}: {e}"))?;
        d2ft::info!("wrote dist report to {report_path}");
    }
    let t = &r.train;
    println!("backend              {} (dist)", t.backend);
    println!("scheduler            {}", t.scheduler);
    println!(
        "workers              {} ({}, {} transport, {} wire)",
        r.n_workers, r.exchange, r.transport, r.compress
    );
    println!("batches              {}", t.batches);
    println!("final train loss     {:.4}", t.final_train_loss);
    println!("test top-1           {}", pct(t.test_top1));
    println!("test loss            {:.4}", t.test_loss);
    println!("compute fraction     {}", pct(t.compute_fraction));
    println!("comm fraction(model) {}", pct(t.comm_fraction));
    println!(
        "grad bytes uplink    {} measured ({} unmasked) -> {} saved",
        fmt_bytes(r.wire.up_bytes),
        fmt_bytes(r.wire.dense_up_bytes),
        pct(r.grad_savings)
    );
    println!("bytes downlink       {}", fmt_bytes(r.wire.down_bytes));
    let ring_total: u64 = r.ring_bytes.iter().map(|&(tx, rx)| tx + rx).sum();
    if ring_total > 0 {
        println!(
            "bytes ring links     {} (worker<->worker, off the aggregator)",
            fmt_bytes(ring_total)
        );
    }
    println!("bytes modeled        {}", fmt_bytes(r.modeled_wire_bytes));
    println!(
        "bytes transport      {} out / {} in over {} frames (whole frames incl. control)",
        fmt_bytes(r.socket.bytes_sent),
        fmt_bytes(r.socket.bytes_recv),
        r.socket.frames_sent + r.socket.frames_recv
    );
    println!(
        "bytes pretrain       {} (dense; excluded above)",
        fmt_bytes(r.pretrain_wire.total_bytes())
    );
    println!("mean step (measured) {:.3}ms", r.mean_step_ms);
    println!("straggler (measured) {:.3}ms/batch", t.straggler_ms);
    println!("worker utilization   {}", pct(r.worker_utilization));
    println!("worker imbalance     {:.4}", r.worker_imbalance);
    println!(
        "recovery             {} evictions, {} joins, {} reconnects, {} corrupt frames, \
         {} resends, {} aggregator restarts",
        r.evictions, r.joins, r.reconnects, r.frames_corrupt, r.resends, r.aggregator_restarts
    );
    if t.calib_epochs > 0 {
        println!(
            "exec-time calib      x{:.3} (p_f x{:.3}, p_o x{:.3}) over {} epochs; \
             model-vs-measured drift {}",
            t.calib_scale,
            t.calib_scale_full,
            t.calib_scale_fwd,
            t.calib_epochs,
            pct(t.makespan_drift)
        );
    } else {
        println!("exec-time calib      off (paper V100 table; no completed epoch)");
    }
    println!(
        "encode buffers       {} fresh / {} recycled",
        r.encode_buf_fresh, r.encode_buf_reused
    );
    println!("wall time            {:.1}s", t.wall_s);
    Ok(())
}

#[cfg(not(feature = "native"))]
fn run_dist(_args: &d2ft::util::cli::Args, _cfg: TrainerConfig) -> Result<()> {
    anyhow::bail!("--dist needs the `native` feature (rebuild with default features)")
}
