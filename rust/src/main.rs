//! `repro` — the D2FT leader binary.
//!
//! Subcommands:
//!   repro train       [flags]   one fine-tuning run, any scheduler
//!   repro serve       [flags]   multi-tenant LoRA fine-tuning service
//!   repro job <action> --connect host:port   submit | status | result | shutdown
//!   repro dist-worker --connect host:port    join a TCP dist cluster
//!   repro experiment  <id>      regenerate a paper table/figure
//!   repro list                  list experiments
//!   repro info                  backend/model summary
//!
//! `--backend native` (the default) needs no setup at all; `--backend
//! xla` needs a build with `--features xla` plus `make artifacts`.
//! `repro train --dist --workers K` runs the real data-parallel trainer
//! (K worker replicas, masked-gradient exchange, measured bytes).
//! `--transport tcp` moves the exchange onto real sockets: the
//! aggregator listens and forks K `repro dist-worker` subprocesses, or
//! — with `--no-spawn` — waits for workers launched by hand (on this
//! machine or any other) via `repro dist-worker --connect host:port`.
//! Numerics are bitwise identical across transports.
//!
//! `repro train --config run.json` reads a serialized `JobSpec` as run
//! defaults; flags given explicitly on the command line still win.
//! `repro serve --listen host:port --max-tenants N` runs the job-spec
//! service; `repro job submit --connect host:port --spec job.json`
//! talks to it over one-JSON-object-per-line.

use anyhow::Result;

use d2ft::backend::{provider_for, BackendKind, BackendProvider};
use d2ft::cluster::ExecMode;
use d2ft::config::JobSpec;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::experiments::{list_experiments, run_experiment, ExperimentCtx};
use d2ft::metrics::{fmt_bytes, pct};
use d2ft::schedule::Budget;
use d2ft::scores::{Metric, ScoreConfig};
use d2ft::util::cli::Cli;
use d2ft::util::json::{num, obj, s, Json};

fn cli() -> Cli {
    Cli::new("repro", "D2FT: Distributed Dynamic Fine-Tuning (paper reproduction)")
        .positional(
            "command",
            "train | serve | job <action> | dist-worker | experiment <id> | list | info",
        )
        .positional(
            "arg",
            "experiment id for `experiment`; submit|status|result|shutdown for `job`",
        )
        .flag(
            "backend",
            "native",
            "compute backend: native (pure Rust, zero setup) | xla (PJRT artifacts)",
        )
        .flag("model", "mini", "native model preset: mini | small (ViT-small-like, 74 subnets)")
        .flag("artifacts", "artifacts", "artifacts directory (xla backend only; make artifacts)")
        .flag(
            "threads",
            "1",
            "matmul kernel threads (native backend; 1 = serial default, 0 = auto/per-core)",
        )
        .flag("scale", "1.0", "experiment run-length scale factor")
        .section("RUN")
        .flag(
            "config",
            "",
            "JSON JobSpec file supplying run defaults (explicit flags still win)",
        )
        .flag("dataset", "c100", "c10 | c100 | cars")
        .flag(
            "scheduler",
            "d2ft",
            "d2ft | standard | random | dpruning-m | dpruning-mg | moe | scaler-max|min|0.1|0.2",
        )
        .flag("batches", "30", "fine-tuning batches")
        .flag("pretrain-batches", "10", "synthetic pre-training batches")
        .flag("train-size", "480", "training examples")
        .flag("test-size", "160", "test examples")
        .flag("micros", "5", "micro-batches per batch")
        .flag("n-full", "3", "p_f micro-batches per device per batch")
        .flag("n-fwd", "1", "p_o micro-batches per device per batch")
        .flag("lr", "0.03", "SGD learning rate")
        .flag("seed", "17", "run seed")
        .flag("backward-score", "weightmag", "fisher|gradmag|taylor|weightmag")
        .flag("forward-score", "fisher", "fisher|gradmag|taylor|weightmag")
        .flag("partition-group", "1", "heads per subnet (Table V)")
        .flag("lora-rank", "0", "LoRA adapter rank (0 = full FT)")
        .flag("eval-every", "0", "evaluate test top-1 every N batches")
        .switch("serial", "serial cluster execution (reference path; same metrics)")
        .switch(
            "batch-accum",
            "one aggregated update per batch (the dist semantics) instead of per-micro",
        )
        .section("DIST & WIRE")
        .switch(
            "dist",
            "real data-parallel training: worker replicas + masked-gradient exchange (native)",
        )
        .flag(
            "workers",
            "0",
            "engine worker threads (0 = one per simulated device; with --dist: 0 = 4 replicas; \
             with serve: 0 = 2 replicas)",
        )
        .flag(
            "exchange",
            "allreduce",
            "dist gradient exchange: allreduce | ps (parameter server) | ring | hier \
             (two-level ring through group leaders)",
        )
        .flag(
            "compress",
            "none",
            "dist gradient wire compression: none | int8 | int4 (quantized, error feedback) | \
             topk[:PCT] (top-k sparsification)",
        )
        .flag(
            "ring-group",
            "0",
            "hier exchange: workers per group (0 = ceil(sqrt(K)))",
        )
        .flag(
            "wire",
            "f32",
            "dist gradient wire precision: f32 (lossless) | f16 (half the bytes, lossy)",
        )
        .flag(
            "transport",
            "channel",
            "dist/serve link transport: channel (in-process) | tcp (real sockets)",
        )
        .flag(
            "listen",
            "127.0.0.1:0",
            "bind address: the tcp aggregator (dist) or the control plane (serve); port 0 = \
             ephemeral",
        )
        .flag("connect", "", "dist-worker / job: server address to reach (host:port)")
        .switch(
            "no-spawn",
            "tcp transport: do not fork dist-worker subprocesses; wait for external workers",
        )
        .switch(
            "no-overlap",
            "serialize each dist worker's encode+upload after its compute (default overlaps)",
        )
        .switch(
            "no-calibrate",
            "keep the paper's V100 exec-time model instead of recalibrating from measured times",
        )
        .section("FAULTS & RECOVERY")
        .flag(
            "fault",
            "",
            "scripted faults: train --dist takes `W:PLAN,...`, dist-worker takes `PLAN` \
             (PLAN = kill-after-micro=N | stall-ms=M@N | drop-uplink=N | rejoin-at-epoch=E | \
             reset-after-frame=N | corrupt-frame=N | delay-ms=M@N | partition-ms=M@E, \
             ';'-joined; the last four act at the network layer)",
        )
        .flag("heartbeat-ms", "500", "dist worker heartbeat interval in ms (0 = disabled)")
        .flag("liveness-misses", "4", "missed heartbeats before a dist worker is declared lost")
        .flag("checkpoint-dir", "", "train --dist: write epoch-boundary checkpoints here")
        .flag(
            "checkpoint-retain",
            "2",
            "train --dist: epoch checkpoints kept after rotation (older ones are deleted)",
        )
        .flag(
            "resume",
            "",
            "train --dist: resume from a checkpoint file, or from a checkpoint *directory* \
             (crash recovery: picks the newest loadable checkpoint + the progress record); \
             skips pre-training",
        )
        .flag(
            "halt-after-batch",
            "",
            "train --dist: crash simulation — exit abruptly right after completing this many \
             batches (progress record on disk, no shutdown handshake); pair with --resume",
        )
        .section("OBSERVABILITY")
        .flag(
            "report-json",
            "",
            "write the run/service report as JSON to this path (train, train --dist, serve)",
        )
        .flag(
            "trace-out",
            "",
            "train --dist: write a merged Chrome trace-event JSON here (open in Perfetto; \
             one lane per worker plus the aggregator)",
        )
        .flag(
            "metrics-addr",
            "",
            "serve live Prometheus metrics on this address (train --dist and serve; \
             e.g. 127.0.0.1:9464; /metrics text + /json dump)",
        )
        .switch("quiet", "suppress info logging")
        .section("SERVE & JOBS")
        .flag("max-tenants", "4", "serve: distinct tenants with active jobs at once")
        .flag("round-batches", "4", "serve: max fine-tuning batches per admitted round")
        .flag(
            "round-micros",
            "32",
            "serve: per-replica micro-step capacity per admission round (knapsack bin size)",
        )
        .flag("job-id", "0", "job status|result: which job to query")
        .flag("spec", "", "job submit: JSON JobSpec file to submit")
        .flag("tenant", "", "job submit: shorthand for a default spec under this tenant")
}

fn main() -> Result<()> {
    d2ft::util::log::init();
    let args = match cli().parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.get_bool("quiet") {
        d2ft::util::log::set_level(d2ft::util::log::Level::Warn);
    }
    let open_provider_for = |model: &str| -> Result<Box<dyn BackendProvider>> {
        let kind = BackendKind::parse(args.get("backend"))?;
        match kind {
            #[cfg(feature = "native")]
            BackendKind::Native => {
                let spec = d2ft::config::NativeSpecBuilder::preset(model)?
                    .threads(args.get_usize("threads")?)
                    .build()?;
                Ok(Box::new(d2ft::backend::native::NativeProvider::new(spec)))
            }
            _ => {
                anyhow::ensure!(
                    matches!(model.to_ascii_lowercase().as_str(), "mini" | "tiny"),
                    "--model presets apply to the native backend only"
                );
                provider_for(kind, std::path::Path::new(args.get("artifacts")))
            }
        }
    };
    let open_provider = || open_provider_for(args.get("model"));
    let command = args.positional(0).unwrap_or("info").to_string();
    match command.as_str() {
        "list" => {
            for (id, desc) in list_experiments() {
                println!("{id:<10} {desc}");
            }
            Ok(())
        }
        "info" => {
            let provider = open_provider()?;
            let m = provider.model_config();
            println!("backend         {}", provider.label());
            println!(
                "model           ViT d{} x{}L x{}H, {}x{} px, {} classes",
                m.dim, m.depth, m.heads, m.img_size, m.img_size, m.classes
            );
            println!(
                "micro-batch     {} (variants {:?})",
                provider.micro_batch(),
                provider.mb_variants()
            );
            println!(
                "parameters      {} tensors, {} elems",
                provider.n_params(),
                provider.total_elems()
            );
            println!(
                "lora ranks      {:?} (standard {})",
                provider.lora_ranks(),
                provider.lora_standard_rank()
            );
            println!(
                "body subnets    {} (+2 = {} devices)",
                m.body_subnets(),
                m.body_subnets() + 2
            );
            Ok(())
        }
        "dist-worker" => run_dist_worker(&args),
        "serve" => run_serve(&args),
        "job" => run_job(&args),
        "experiment" => {
            let id = args
                .positional(1)
                .ok_or_else(|| anyhow::anyhow!("usage: repro experiment <id> (see `repro list`)"))?
                .to_string();
            let provider = open_provider()?;
            let mut ctx = ExperimentCtx::new(provider.as_ref());
            ctx.scale = args.get_f64("scale")?;
            ctx.seed = args.get_u64("seed")?;
            run_experiment(&ctx, &id)?;
            Ok(())
        }
        "train" => {
            let (cfg, model) = train_config(&args)?;
            if args.get_bool("dist") {
                return run_dist(&args, cfg, &model);
            }
            let provider = open_provider_for(&model)?;
            let mut trainer = Trainer::new(provider.as_ref(), cfg)?;
            let r = trainer.run()?;
            let report_path = args.get("report-json");
            if !report_path.is_empty() {
                let doc = d2ft::report::train_report_json(&r);
                std::fs::write(report_path, doc.to_string_pretty())
                    .map_err(|e| anyhow::anyhow!("writing {report_path}: {e}"))?;
                d2ft::info!("wrote train report to {report_path}");
            }
            println!("backend              {}", r.backend);
            println!("scheduler            {}", r.scheduler);
            println!("batches              {}", r.batches);
            println!("final train loss     {:.4}", r.final_train_loss);
            println!("test top-1           {}", pct(r.test_top1));
            println!("test loss            {:.4}", r.test_loss);
            println!("compute fraction     {}", pct(r.compute_fraction));
            println!("comm fraction        {}", pct(r.comm_fraction));
            println!("workload variance    {:.4}", r.workload_variance);
            println!("mean exec (model)    {:.2}ms", r.mean_exec_ms);
            println!("makespan (model)     {:.2}ms", r.makespan_ms);
            println!("engine               {}", r.engine);
            println!("device utilization   {}", pct(r.utilization));
            println!("imbalance            {:.4}", r.imbalance);
            println!("straggler (measured) {:.3}ms/batch", r.straggler_ms);
            println!("wall time            {:.1}s", r.wall_s);
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", cli().usage());
            std::process::exit(2);
        }
    }
}

/// Resolve `repro train`'s run configuration: `--config` (a serialized
/// [`JobSpec`]) supplies defaults, explicitly-passed flags override
/// them, and everything funnels through the [`TrainerConfig`] builder.
/// Returns the config plus the model preset to open.
fn train_config(args: &d2ft::util::cli::Args) -> Result<(TrainerConfig, String)> {
    let path = args.get("config");
    let file_spec: Option<JobSpec> = if path.is_empty() {
        None
    } else {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading --config {path}: {e}"))?;
        Some(JobSpec::parse(&text)?)
    };
    // A flag wins when passed explicitly, or when there is no config
    // file to defer to.
    let fromcli = |flag: &str| args.is_set(flag) || file_spec.is_none();
    let spec = file_spec.clone().unwrap_or_else(|| JobSpec::default_for("cli"));

    let micros =
        if fromcli("micros") { args.get_usize("micros")? } else { spec.micros_per_batch };
    let n_full = if fromcli("n-full") { args.get_usize("n-full")? } else { spec.budget_full };
    let n_fwd = if fromcli("n-fwd") { args.get_usize("n-fwd")? } else { spec.budget_fwd };
    let model = if args.is_set("model") || file_spec.is_none() {
        args.get("model").to_string()
    } else {
        spec.model.clone()
    };
    let cfg = TrainerConfig::builder()
        .dataset(if fromcli("dataset") {
            SyntheticKind::parse(args.get("dataset"))?
        } else {
            spec.dataset
        })
        .train_size(if fromcli("train-size") {
            args.get_usize("train-size")?
        } else {
            spec.train_size
        })
        .test_size(if fromcli("test-size") { args.get_usize("test-size")? } else { spec.test_size })
        .micros_per_batch(micros)
        .batches(if fromcli("batches") { args.get_usize("batches")? } else { spec.batches })
        .lr(if fromcli("lr") { args.get_f32("lr")? } else { spec.lr })
        .budget(Budget::uniform(micros, n_full, n_fwd))
        .scheduler(if fromcli("scheduler") {
            SchedulerKind::parse(args.get("scheduler"))?
        } else {
            spec.scheduler
        })
        .scores(ScoreConfig {
            backward: Metric::parse(args.get("backward-score"))?,
            forward: Metric::parse(args.get("forward-score"))?,
        })
        .exec(if args.get_bool("serial") {
            ExecMode::Serial
        } else {
            ExecMode::Parallel { workers: args.get_usize("workers")? }
        })
        .partition_group(args.get_usize("partition-group")?)
        .seed(if fromcli("seed") { args.get_u64("seed")? } else { spec.seed })
        .pretrain_batches(if fromcli("pretrain-batches") {
            args.get_usize("pretrain-batches")?
        } else {
            spec.pretrain_batches
        })
        .eval_every(args.get_usize("eval-every")?)
        .lora_rank(if fromcli("lora-rank") { args.get_usize("lora-rank")? } else { spec.lora_rank })
        .update(if args.get_bool("batch-accum") || args.get_bool("dist") {
            UpdateMode::BatchAccum
        } else {
            UpdateMode::PerMicro
        })
        .build()?;
    Ok((cfg, model))
}

/// `repro dist-worker --connect host:port`: join a TCP dist cluster as
/// one worker replica. Model-agnostic: everything (spec, seed, LoRA
/// rank, wire precision) arrives in the aggregator's Init frame, so
/// the same invocation serves any run — including one on another host.
#[cfg(feature = "native")]
fn run_dist_worker(args: &d2ft::util::cli::Args) -> Result<()> {
    use d2ft::dist::{run_worker_reconnecting, BufPool, FaultPlan};
    use std::sync::Arc;

    let addr = args.get("connect");
    anyhow::ensure!(
        !addr.is_empty(),
        "usage: repro dist-worker --connect <host:port> (the aggregator's --listen address)"
    );
    let plan = FaultPlan::parse(args.get("fault"))?;
    let pool = Arc::new(BufPool::new());
    // The redial window lets this worker outlive an aggregator restart:
    // a dropped link is retried with capped backoff until the window
    // expires, so `--resume` on the aggregator side picks the same
    // replica back up instead of spawning a fresh one.
    run_worker_reconnecting(addr, pool, plan, std::time::Duration::from_secs(60))?;
    d2ft::info!("dist-worker shut down cleanly");
    Ok(())
}

#[cfg(not(feature = "native"))]
fn run_dist_worker(_args: &d2ft::util::cli::Args) -> Result<()> {
    anyhow::bail!("dist-worker needs the `native` feature (rebuild with default features)")
}

/// `repro serve`: run the multi-tenant fine-tuning service until a
/// control-plane client sends `shutdown`, then write the metering
/// report.
#[cfg(feature = "native")]
fn run_serve(args: &d2ft::util::cli::Args) -> Result<()> {
    use d2ft::serve::{serve, ServeConfig};

    let registry = std::sync::Arc::new(d2ft::obs::Registry::new());
    let metrics_addr = args.get("metrics-addr");
    let _metrics_server = if metrics_addr.is_empty() {
        None
    } else {
        let srv = d2ft::obs::MetricsServer::start(metrics_addr, std::sync::Arc::clone(&registry))?;
        d2ft::info!("serving metrics at http://{}/metrics", srv.addr());
        Some(srv)
    };
    let mut cfg = ServeConfig::new();
    cfg.model = args.get("model").to_string();
    cfg.workers = match args.get_usize("workers")? {
        0 => 2,
        w => w,
    };
    cfg.max_tenants = args.get_usize("max-tenants")?;
    cfg.round_batches = args.get_usize("round-batches")?;
    cfg.round_micros = args.get_usize("round-micros")?;
    cfg.tcp = args.get("transport").eq_ignore_ascii_case("tcp");
    cfg.control = Some(args.get("listen").to_string());
    cfg.metrics = Some(std::sync::Arc::clone(&registry));
    let replicas = cfg.workers;
    let model = cfg.model.clone();
    let mut handle = serve(cfg)?;
    let addr = handle.control_addr().unwrap_or("?").to_string();
    println!("serve listening on {addr}");
    d2ft::info!("serve up: {replicas} replicas of {model}; submit via --connect {addr}");
    handle.wait_for_shutdown_request();
    handle.shutdown();
    let report = handle.report_json();
    let report_path = args.get("report-json");
    if !report_path.is_empty() {
        std::fs::write(report_path, report.to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {report_path}: {e}"))?;
        d2ft::info!("wrote serve report to {report_path}");
    }
    let jobs = report.opt("jobs").and_then(|j| j.as_arr().ok()).map(|a| a.len()).unwrap_or(0);
    println!("serve shut down after {jobs} jobs");
    Ok(())
}

#[cfg(not(feature = "native"))]
fn run_serve(_args: &d2ft::util::cli::Args) -> Result<()> {
    anyhow::bail!("serve needs the `native` feature (rebuild with default features)")
}

/// `repro job submit|status|result|shutdown --connect host:port`: one
/// newline-delimited JSON request to a running `repro serve`.
fn run_job(args: &d2ft::util::cli::Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};

    let action = args
        .positional(1)
        .ok_or_else(|| anyhow::anyhow!("usage: repro job <submit|status|result|shutdown>"))?
        .to_string();
    let addr = args.get("connect");
    anyhow::ensure!(
        !addr.is_empty(),
        "usage: repro job {action} --connect <host:port> (the serve --listen address)"
    );
    let request = match action.as_str() {
        "submit" => {
            let spec_path = args.get("spec");
            let spec = if !spec_path.is_empty() {
                let text = std::fs::read_to_string(spec_path)
                    .map_err(|e| anyhow::anyhow!("reading --spec {spec_path}: {e}"))?;
                JobSpec::parse(&text)?
            } else {
                let tenant = args.get("tenant");
                anyhow::ensure!(
                    !tenant.is_empty(),
                    "job submit needs --spec <file.json> or --tenant <name>"
                );
                JobSpec::default_for(tenant)
            };
            obj(vec![("cmd", s("submit")), ("spec", spec.to_json())])
        }
        "status" | "result" => obj(vec![
            ("cmd", s(&action)),
            ("job_id", num(args.get_u64("job-id")? as f64)),
        ]),
        "shutdown" => obj(vec![("cmd", s("shutdown"))]),
        other => anyhow::bail!("unknown job action {other:?} (submit|status|result|shutdown)"),
    };
    let stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to serve at {addr}: {e}"))?;
    let mut writer = stream.try_clone()?;
    writer.write_all(request.to_string_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    anyhow::ensure!(!reply.trim().is_empty(), "serve closed the connection without replying");
    let doc = Json::parse(reply.trim())?;
    let ok = doc.opt("ok").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    if ok == 0.0 {
        anyhow::bail!("serve refused: {}", doc.str_at("error").unwrap_or_default());
    }
    println!("{}", doc.to_string_pretty());
    Ok(())
}

/// `repro train --dist`: the real data-parallel runtime (native only).
#[cfg(feature = "native")]
fn run_dist(args: &d2ft::util::cli::Args, cfg: TrainerConfig, model: &str) -> Result<()> {
    use d2ft::backend::native::{NativeProvider, NativeSpec};
    use d2ft::dist::{
        parse_worker_plans, DistConfig, DistTrainer, ExchangeMode, SpawnMode, TransportKind,
    };

    anyhow::ensure!(
        d2ft::backend::BackendKind::parse(args.get("backend"))?
            == d2ft::backend::BackendKind::Native,
        "--dist runs on the native backend (worker replicas need Send numerics)"
    );
    let spec = NativeSpec::builder_preset(model)?.threads(args.get_usize("threads")?).build()?;
    let provider = NativeProvider::new(spec);
    let workers = match args.get_usize("workers")? {
        0 => 4,
        w => w,
    };
    let transport = match TransportKind::parse(args.get("transport"))? {
        TransportKind::Tcp { .. } => TransportKind::Tcp {
            listen: args.get("listen").to_string(),
            spawn: if args.get_bool("no-spawn") {
                SpawnMode::External
            } else {
                SpawnMode::Processes
            },
        },
        kind => kind,
    };
    let to_path = |flag: &str| -> Option<std::path::PathBuf> {
        let v = args.get(flag);
        (!v.is_empty()).then(|| std::path::PathBuf::from(v))
    };
    // The registry is shared with the trainer; starting the server
    // before the run means a scrape mid-training sees live values.
    let registry = std::sync::Arc::new(d2ft::obs::Registry::new());
    let metrics_addr = args.get("metrics-addr");
    let _metrics_server = if metrics_addr.is_empty() {
        None
    } else {
        let srv = d2ft::obs::MetricsServer::start(metrics_addr, std::sync::Arc::clone(&registry))?;
        d2ft::info!("serving metrics at http://{}/metrics", srv.addr());
        Some(srv)
    };
    let dcfg = DistConfig::builder(cfg, workers)
        .exchange(ExchangeMode::parse(args.get("exchange"))?)
        .transport(transport)
        .overlap(!args.get_bool("no-overlap"))
        .wire_precision(d2ft::dist::WirePrecision::parse(args.get("wire"))?)
        .compress(d2ft::dist::WireCompression::parse(args.get("compress"))?)
        .ring_group(args.get_usize("ring-group")?)
        .calibrate(!args.get_bool("no-calibrate"))
        .heartbeat_ms(args.get_u64("heartbeat-ms")?)
        .liveness_misses(args.get_usize("liveness-misses")? as u32)
        .faults(parse_worker_plans(args.get("fault"))?)
        .checkpoint_dir(to_path("checkpoint-dir"))
        .checkpoint_retain(args.get_usize("checkpoint-retain")?)
        .resume_from(to_path("resume"))
        .halt_after_batch({
            let v = args.get("halt-after-batch");
            if v.is_empty() {
                None
            } else {
                Some(v.parse::<usize>().map_err(|e| {
                    anyhow::anyhow!("--halt-after-batch {v:?}: {e} (expected a batch count)")
                })?)
            }
        })
        .trace_out(to_path("trace-out"))
        .metrics(Some(std::sync::Arc::clone(&registry)))
        .build()?;
    let mut trainer = DistTrainer::new(&provider, dcfg)?;
    let r = trainer.run()?;
    let report_path = args.get("report-json");
    if !report_path.is_empty() {
        std::fs::write(report_path, r.to_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {report_path}: {e}"))?;
        d2ft::info!("wrote dist report to {report_path}");
    }
    let t = &r.train;
    println!("backend              {} (dist)", t.backend);
    println!("scheduler            {}", t.scheduler);
    println!(
        "workers              {} ({}, {} transport, {} wire)",
        r.n_workers, r.exchange, r.transport, r.compress
    );
    println!("batches              {}", t.batches);
    println!("final train loss     {:.4}", t.final_train_loss);
    println!("test top-1           {}", pct(t.test_top1));
    println!("test loss            {:.4}", t.test_loss);
    println!("compute fraction     {}", pct(t.compute_fraction));
    println!("comm fraction(model) {}", pct(t.comm_fraction));
    println!(
        "grad bytes uplink    {} measured ({} unmasked) -> {} saved",
        fmt_bytes(r.wire.up_bytes),
        fmt_bytes(r.wire.dense_up_bytes),
        pct(r.grad_savings)
    );
    println!("bytes downlink       {}", fmt_bytes(r.wire.down_bytes));
    let ring_total: u64 = r.ring_bytes.iter().map(|&(tx, rx)| tx + rx).sum();
    if ring_total > 0 {
        println!(
            "bytes ring links     {} (worker<->worker, off the aggregator)",
            fmt_bytes(ring_total)
        );
    }
    println!("bytes modeled        {}", fmt_bytes(r.modeled_wire_bytes));
    println!(
        "bytes transport      {} out / {} in over {} frames (whole frames incl. control)",
        fmt_bytes(r.socket.bytes_sent),
        fmt_bytes(r.socket.bytes_recv),
        r.socket.frames_sent + r.socket.frames_recv
    );
    println!(
        "bytes pretrain       {} (dense; excluded above)",
        fmt_bytes(r.pretrain_wire.total_bytes())
    );
    println!("mean step (measured) {:.3}ms", r.mean_step_ms);
    println!("straggler (measured) {:.3}ms/batch", t.straggler_ms);
    println!("worker utilization   {}", pct(r.worker_utilization));
    println!("worker imbalance     {:.4}", r.worker_imbalance);
    println!(
        "recovery             {} evictions, {} joins, {} reconnects, {} corrupt frames, \
         {} resends, {} aggregator restarts",
        r.evictions, r.joins, r.reconnects, r.frames_corrupt, r.resends, r.aggregator_restarts
    );
    if t.calib_epochs > 0 {
        println!(
            "exec-time calib      x{:.3} (p_f x{:.3}, p_o x{:.3}) over {} epochs; \
             model-vs-measured drift {}",
            t.calib_scale,
            t.calib_scale_full,
            t.calib_scale_fwd,
            t.calib_epochs,
            pct(t.makespan_drift)
        );
    } else {
        println!("exec-time calib      off (paper V100 table; no completed epoch)");
    }
    println!(
        "encode buffers       {} fresh / {} recycled",
        r.encode_buf_fresh, r.encode_buf_reused
    );
    println!("wall time            {:.1}s", t.wall_s);
    Ok(())
}

#[cfg(not(feature = "native"))]
fn run_dist(_args: &d2ft::util::cli::Args, _cfg: TrainerConfig, _model: &str) -> Result<()> {
    anyhow::bail!("--dist needs the `native` feature (rebuild with default features)")
}
