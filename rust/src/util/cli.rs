//! Declarative CLI flag parsing (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, defaults,
//! and generated `--help`. Used by the `repro` binary and examples.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One declared flag.
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative arg parser: declare flags, then `parse`.
pub struct Cli {
    program: &'static str,
    about: &'static str,
    flags: Vec<FlagSpec>,
    /// `(first flag index, title)` — section headers for `usage()`.
    sections: Vec<(usize, &'static str)>,
    positional: Vec<(&'static str, &'static str)>,
}

/// Parse result with typed accessors.
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    set: std::collections::BTreeSet<String>,
    positional: Vec<String>,
}

impl Cli {
    /// Parser for `program` with a one-line description.
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, flags: Vec::new(), sections: Vec::new(), positional: Vec::new() }
    }

    /// Start a named flag group; every flag declared after this call
    /// (until the next `section`) renders under the title in `--help`.
    pub fn section(mut self, title: &'static str) -> Self {
        self.sections.push((self.flags.len(), title));
        self
    }

    /// Declare `--name <value>` with a default.
    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required `--name <value>` flag.
    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_bool: false });
        self
    }

    /// Declare a boolean `--name` switch (default false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_bool: true });
        self
    }

    /// Declare a positional argument (for `repro experiment <id>`).
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }

    /// Render the generated `--help` text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positional {
            out.push_str(&format!(" <{p}>"));
        }
        out.push_str(" [flags]\n\nFLAGS:\n");
        for (i, f) in self.flags.iter().enumerate() {
            if let Some(&(_, title)) = self.sections.iter().find(|&&(at, _)| at == i) {
                out.push_str(&format!("\n{title}:\n"));
            }
            let head = if f.is_bool {
                format!("  --{}", f.name)
            } else if let Some(d) = &f.default {
                format!("  --{} <v> (default {})", f.name, d)
            } else {
                format!("  --{} <v> (required)", f.name)
            };
            out.push_str(&format!("{head:<40} {}\n", f.help));
        }
        for (p, h) in &self.positional {
            out.push_str(&format!("  <{p}>{:<34} {h}\n", ""));
        }
        out
    }

    /// Parse an argv slice (without the program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        let mut set = std::collections::BTreeSet::new();
        let mut positional = Vec::new();
        for f in &self.flags {
            if f.is_bool {
                bools.insert(f.name.to_string(), false);
            } else if let Some(d) = &f.default {
                values.insert(f.name.to_string(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(name) = arg.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}\n\n{}", self.usage()))?;
                if spec.is_bool {
                    bools.insert(name.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?,
                    };
                    values.insert(name.to_string(), v);
                }
                set.insert(name.to_string());
            } else {
                positional.push(arg);
            }
        }
        for f in &self.flags {
            if !f.is_bool && !values.contains_key(f.name) {
                bail!("missing required flag --{}\n\n{}", f.name, self.usage());
            }
        }
        if positional.len() > self.positional.len() {
            bail!("unexpected positional args {positional:?}\n\n{}", self.usage());
        }
        Ok(Args { values, bools, set, positional })
    }

    /// Parse the process args.
    pub fn parse(&self) -> Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

impl Args {
    /// Raw string value of a flag ("" if undeclared).
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    /// Flag value parsed as `usize`.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get(name).parse()?)
    }

    /// Flag value parsed as `u64`.
    pub fn get_u64(&self, name: &str) -> Result<u64> {
        Ok(self.get(name).parse()?)
    }

    /// Flag value parsed as `f64`.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        Ok(self.get(name).parse()?)
    }

    /// Flag value parsed as `f32`.
    pub fn get_f32(&self, name: &str) -> Result<f32> {
        Ok(self.get(name).parse()?)
    }

    /// Boolean switch value (false if absent).
    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    /// Whether the user passed `--name` explicitly (vs taking the
    /// default). Lets `--config` files fill defaults without clobbering
    /// flags given on the command line.
    pub fn is_set(&self, name: &str) -> bool {
        self.set.contains(name)
    }

    /// The `i`-th positional argument, if given.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// Comma-separated list -> Vec<usize>.
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| Ok(s.trim().parse()?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .flag("steps", "100", "steps")
            .flag("lr", "0.01", "learning rate")
            .required("dataset", "dataset name")
            .switch("verbose", "verbose")
            .positional("cmd", "command")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli()
            .parse_from(argv(&["run", "--dataset", "c10", "--steps=5", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 5);
        assert_eq!(a.get_f64("lr").unwrap(), 0.01);
        assert_eq!(a.get("dataset"), "c10");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(0), Some("run"));
    }

    #[test]
    fn missing_required() {
        assert!(cli().parse_from(argv(&["run"])).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cli().parse_from(argv(&["--nope", "1", "--dataset", "x"])).is_err());
    }

    #[test]
    fn is_set_tracks_explicit_flags_only() {
        let a = cli().parse_from(argv(&["--dataset", "c10", "--steps=5", "--verbose"])).unwrap();
        assert!(a.is_set("steps"));
        assert!(a.is_set("dataset"));
        assert!(a.is_set("verbose"));
        assert!(!a.is_set("lr")); // default taken, not passed
    }

    #[test]
    fn sections_render_in_usage() {
        let c = Cli::new("t", "test")
            .section("RUN")
            .flag("steps", "1", "steps")
            .section("WIRE")
            .flag("compress", "none", "codec");
        let u = c.usage();
        let run = u.find("RUN:").expect("RUN header");
        let wire = u.find("WIRE:").expect("WIRE header");
        let steps = u.find("--steps").unwrap();
        let compress = u.find("--compress").unwrap();
        assert!(run < steps && steps < wire && wire < compress);
    }

    #[test]
    fn list_parsing() {
        let a = Cli::new("t", "")
            .flag("sizes", "4,8,16", "")
            .parse_from(argv(&[]))
            .unwrap();
        assert_eq!(a.get_usize_list("sizes").unwrap(), vec![4, 8, 16]);
    }
}
