//! Leveled stderr logger with wall-clock-relative timestamps.
//!
//! Level is process-global (`D2FT_LOG=debug|info|warn|error`, default
//! info). The macros are cheap when the level is filtered out.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity (ascending).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Verbose diagnostics.
    Debug = 0,
    /// Normal progress output (default).
    Info = 1,
    /// Unexpected but non-fatal conditions.
    Warn = 2,
    /// Failures.
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize from the environment; call once at startup (idempotent).
pub fn init() {
    START.get_or_init(Instant::now);
    let lvl = match std::env::var("D2FT_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
}

/// Set the process-global level.
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Whether messages at `lvl` are currently emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Emit one message (used by the `debug!`/`info!`/`warn_!` macros).
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
/// Log at [`Level::Warn`] (named `warn_!` to avoid the built-in lint name).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
