//! Leveled stderr logger with wall-clock-relative timestamps.
//!
//! Level is process-global (`D2FT_LOG=debug|info|warn|error`,
//! case-insensitive, default info; an unrecognized value warns once
//! listing the valid names rather than being silently ignored). The
//! macros are cheap when the level is filtered out. Every emitted
//! message also lands as an `obs::trace` instant when tracing is armed,
//! so log lines show up inline on the Perfetto timeline.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity (ascending).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Verbose diagnostics.
    Debug = 0,
    /// Normal progress output (default).
    Info = 1,
    /// Unexpected but non-fatal conditions.
    Warn = 2,
    /// Failures.
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

/// Parse a level name, case-insensitively. `None` for anything that is
/// not one of `debug|info|warn|error`.
pub fn parse_level(name: &str) -> Option<Level> {
    match name.trim().to_ascii_lowercase().as_str() {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

/// Initialize from the environment; call once at startup (idempotent).
pub fn init() {
    START.get_or_init(Instant::now);
    let lvl = match std::env::var("D2FT_LOG") {
        Ok(raw) => match parse_level(&raw) {
            Some(lvl) => lvl,
            None => {
                warn_bad_level(&raw);
                Level::Info
            }
        },
        Err(_) => Level::Info,
    };
    set_level(lvl);
}

/// Warn exactly once per process about an unrecognized `D2FT_LOG`
/// value, listing the valid names (init is called from several entry
/// points and must stay idempotent on stderr too).
fn warn_bad_level(raw: &str) {
    static WARNED: OnceLock<()> = OnceLock::new();
    WARNED.get_or_init(|| {
        log(
            Level::Warn,
            format_args!(
                "D2FT_LOG={raw:?} is not a log level; valid values are \
                 debug|info|warn|error (case-insensitive), defaulting to info"
            ),
        );
    });
}

/// Set the process-global level.
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Whether messages at `lvl` are currently emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Emit one message (used by the `debug!`/`info!`/`warn_!` macros).
/// When trace recording is armed, the emission is mirrored as a trace
/// instant in the `log` category so it appears on the step timeline.
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    crate::obs::trace::instant(
        "log",
        match lvl {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        },
    );
    eprintln!("[{t:9.3}s {tag}] {args}");
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
/// Log at [`Level::Warn`] (named `warn_!` to avoid the built-in lint name).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }

    #[test]
    fn level_names_parse_case_insensitively() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level(" warn "), Some(Level::Warn));
        assert_eq!(parse_level("ERROR"), Some(Level::Error));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level("2"), None);
    }
}
