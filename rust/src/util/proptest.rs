//! Seeded property-testing harness (no `proptest` offline).
//!
//! `check(name, cases, |g| { ... })` runs a property over `cases`
//! generated inputs; on failure it reports the failing case index and the
//! seed that reproduces it. Generators draw from a [`Gen`] handle that
//! wraps the crate RNG, so every failure is replayable:
//! `D2FT_PROP_SEED=<seed> cargo test <name>`.

use super::rng::Rng;

/// Generator handle passed to properties.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of length `len` with elements from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// The underlying RNG, for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded inputs; panics with a replayable seed on
/// the first failure. `prop` returns `Err(reason)` or panics to fail.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("D2FT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let cases_to_run = if base_seed.is_some() { 1 } else { cases };
    for case in 0..cases_to_run {
        let seed = base_seed.unwrap_or_else(|| {
            // Stable per (property name, case index): failures reproduce
            // without any env var as long as the property is unchanged.
            super::rng::fnv1a(name) ^ case as u64
        });
        let mut g = Gen { rng: Rng::new(seed) };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        let failed = match &outcome {
            Ok(Ok(())) => None,
            Ok(Err(reason)) => Some(reason.clone()),
            Err(_) => Some("panicked".to_string()),
        };
        if let Some(reason) = failed {
            panic!(
                "property {name:?} failed on case {case}/{cases}: {reason}\n\
                 reproduce with D2FT_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sorted-after-sort", 50, |g| {
            let len = g.usize_in(0, 20);
            let mut v = g.vec(len, |g| g.usize_in(0, 100));
            v.sort_unstable();
            if v.windows(2).all(|w| w[0] <= w[1]) {
                Ok(())
            } else {
                Err(format!("not sorted: {v:?}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with D2FT_PROP_SEED=")]
    fn failure_reports_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        check("gen-bounds", 100, |g| {
            let x = g.usize_in(3, 9);
            let y = g.f64_in(-1.0, 1.0);
            if (3..=9).contains(&x) && (-1.0..1.0).contains(&y) {
                Ok(())
            } else {
                Err(format!("out of bounds: {x} {y}"))
            }
        });
    }
}
