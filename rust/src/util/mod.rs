//! Offline-build substrates: deterministic RNG, JSON, CLI parsing,
//! logging, property testing, and bench timing.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so everything that would normally come from `rand`, `serde`,
//! `clap`, `proptest`, or `criterion` is implemented here (see DESIGN.md
//! "Offline-build substrates").

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
