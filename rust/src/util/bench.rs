//! Timing/statistics harness for `[[bench]] harness = false` targets
//! (no `criterion` offline).
//!
//! Usage in a bench target:
//! ```ignore
//! let mut b = Bench::new("knapsack-74x5");
//! b.run(|| schedule(&scores, &caps));
//! b.report(); // name, mean, p50, p95, min, iters
//! ```
//! Warmup + adaptive iteration count; reports wall-clock statistics in a
//! stable single-line format so `bench_output.txt` diffs cleanly.

use std::time::{Duration, Instant};

/// Adaptive wall-clock timing harness for one benchmark case.
pub struct Bench {
    name: String,
    samples: Vec<Duration>,
    target_time: Duration,
    max_iters: usize,
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Busy-wait for `ms` milliseconds — simulated *compute*: the cluster
/// engine spins each modeled device for its modeled duration, because
/// compute genuinely occupies a core. Deliberately NOT used by the dist
/// runtime's simulated NIC (`dist::trainer::sim_wire_delay`), which
/// sleeps instead: a DMA transfer does not burn CPU, and spinning there
/// would steal cores from the compute threads and fake the
/// comm/compute-overlap measurement.
pub fn spin_for_ms(ms: f64) {
    if ms <= 0.0 {
        return;
    }
    let target = Duration::from_secs_f64(ms / 1e3);
    let t0 = Instant::now();
    while t0.elapsed() < target {
        std::hint::spin_loop();
    }
}

impl Bench {
    /// Named bench case with default budget (2 s / 10k iters).
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            samples: Vec::new(),
            target_time: Duration::from_secs(2),
            max_iters: 10_000,
        }
    }

    /// Cap total measurement time (default 2 s).
    pub fn target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Cap the number of measured iterations.
    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Measure `f` repeatedly until the time budget or iteration cap.
    pub fn run<T>(&mut self, mut f: impl FnMut() -> T) -> &mut Self {
        // Warmup: 3 calls or 10% of budget, whichever first.
        let warm_start = Instant::now();
        for _ in 0..3 {
            black_box(f());
            if warm_start.elapsed() > self.target_time / 10 {
                break;
            }
        }
        let start = Instant::now();
        while start.elapsed() < self.target_time && self.samples.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            self.samples.push(t0.elapsed());
        }
        self
    }

    /// Statistics over the collected samples (panics if none).
    pub fn stats(&self) -> Stats {
        assert!(!self.samples.is_empty(), "no samples for {}", self.name);
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let total: Duration = sorted.iter().sum();
        Stats {
            iters: sorted.len(),
            mean: total / sorted.len() as u32,
            p50: sorted[sorted.len() / 2],
            p95: sorted[((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1)],
            min: sorted[0],
            max: *sorted.last().unwrap(),
        }
    }

    /// Print a one-line stable report and return the stats.
    pub fn report(&self) -> Stats {
        let s = self.stats();
        println!(
            "bench {:<40} mean {:>12} p50 {:>12} p95 {:>12} min {:>12} iters {}",
            self.name,
            fmt_dur(s.mean),
            fmt_dur(s.p50),
            fmt_dur(s.p95),
            fmt_dur(s.min),
            s.iters
        );
        s
    }
}

/// Wall-clock statistics of one bench case.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Measured iterations.
    pub iters: usize,
    /// Mean duration.
    pub mean: Duration,
    /// Median duration.
    pub p50: Duration,
    /// 95th-percentile duration.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

/// Human-readable duration (ns/us/ms/s auto-scaled).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("noop").target_time(Duration::from_millis(20));
        b.run(|| 1 + 1);
        let s = b.stats();
        assert!(s.iters > 0);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn spin_respects_lower_bound() {
        let t0 = Instant::now();
        spin_for_ms(2.0);
        assert!(t0.elapsed() >= Duration::from_millis(2));
        // Non-positive durations return immediately.
        spin_for_ms(0.0);
        spin_for_ms(-1.0);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12ns");
        assert!(fmt_dur(Duration::from_micros(15)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(15)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
