//! Minimal JSON: recursive-descent parser + writer (no `serde` offline).
//!
//! Covers the full JSON grammar the artifact manifests and experiment
//! reports use: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are held as f64; integer accessors check exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable experiment reports).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys -> deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// Required object member.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// Optional object member.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// This value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// This value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// This value as a non-negative exact integer.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// This value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// String member `key` of this object.
    pub fn str_at(&self, key: &str) -> Result<String> {
        Ok(self.get(key).with_context(|| key.to_string())?.as_str()?.to_string())
    }

    /// Integer member `key` of this object.
    pub fn usize_at(&self, key: &str) -> Result<usize> {
        self.get(key).with_context(|| key.to_string())?.as_usize()
    }

    // ---- writer ----------------------------------------------------------

    /// Serialize with indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize on one line.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Number literal.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// String literal.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Array literal.
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&cp)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                let hex2 = std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 6;
                                char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x20 => bail!("control char in string at byte {}", self.i),
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        out.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"config": {"depth": 6, "heads": 6}, "params":
            [{"name": "a_cls", "shape": [1, 1, 192], "offset": 0}],
            "ok": true, "x": null, "f": -1.5e3}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("config").unwrap().usize_at("depth").unwrap(), 6);
        assert_eq!(
            v.get("params").unwrap().as_arr().unwrap()[0].str_at("name").unwrap(),
            "a_cls"
        );
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), -1500.0);
        // reparse of serialization is identical
        let again = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
        let again = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndAé");
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn usize_exactness() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
