//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** generation.
//!
//! Every stochastic component in the crate (data synthesis, random
//! scheduling baseline, property tests) threads one of these explicitly —
//! experiment runs are bit-reproducible from their seed.

/// xoshiro256** by Blackman & Vigna, seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// FNV-1a hash of a string — the crate's standard way to derive a
/// stable seed from a name (parameter init, property-test cases).
pub fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-device / per-shard use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(5);
        let picked = r.choose_k(20, 8);
        assert_eq!(picked.len(), 8);
        let mut s = picked.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
