//! Simulated K-device cluster (DESIGN.md Substitution 1).
//!
//! The paper's distributed claims are about *scheduling*: which (subnet,
//! micro-batch) pairs run where, and what that costs. The numerics run
//! once on the PJRT CPU client — bit-identical to what each simulated
//! device would compute — while this module charges every simulated
//! device the paper's cost model and execution-time model, tracks
//! workloads, and implements the heterogeneity configurations of §IV-D.
//!
//! Since the parallel-engine refactor, the simulated devices are no
//! longer iterated serially: [`engine::Engine`] runs one worker thread
//! per device (or a fixed pool), makes straggler time a *measured*
//! property, and overlaps simulated communication with compute. The
//! serial path survives as [`engine::ExecMode::Serial`], the reference
//! the determinism test compares against.

pub mod cost;
pub mod engine;
pub mod exec_time;
pub mod hetero;
pub mod workload;

pub use cost::CostModel;
pub use engine::{
    run_synthetic, DeviceReport, Engine, EngineConfig, ExecMode, StepReport,
    SyntheticReport, SyntheticRunConfig,
};
pub use exec_time::{ExecTimeModel, OpCalibrator};
pub use hetero::HeteroSpec;
pub use workload::WorkloadTracker;
