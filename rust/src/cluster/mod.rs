//! Simulated K-device cluster (DESIGN.md Substitution 1).
//!
//! The paper's distributed claims are about *scheduling*: which (subnet,
//! micro-batch) pairs run where, and what that costs. The numerics run
//! once on the PJRT CPU client — bit-identical to what each simulated
//! device would compute — while this module charges every simulated
//! device the paper's cost model and execution-time model, tracks
//! workloads, and implements the heterogeneity configurations of §IV-D.

pub mod cost;
pub mod exec_time;
pub mod hetero;
pub mod workload;

pub use cost::CostModel;
pub use exec_time::ExecTimeModel;
pub use hetero::HeteroSpec;
pub use workload::WorkloadTracker;
