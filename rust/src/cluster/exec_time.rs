//! Per-subnet execution-time model (paper Tables II & IV).
//!
//! The paper measures how long one subnet takes to process 1..5
//! micro-batches under `p_f` and `p_o` on their V100. We reproduce the
//! *model*: a calibrated table with linear extrapolation, which the
//! cluster uses to estimate batch makespan (the slowest device gates the
//! step — the straggler effect Table II demonstrates). The table can be
//! calibrated from the paper's numbers or re-measured on this host's
//! PJRT runtime (`calibrate`).

use crate::schedule::table::{Op, ScheduleTable};

/// Milliseconds for a subnet to process n micro-batches, per op kind.
#[derive(Clone, Debug)]
pub struct ExecTimeModel {
    /// `full_ms[n-1]` = time for n micro-batches under p_f.
    full_ms: Vec<f64>,
    /// Same for p_o.
    fwd_ms: Vec<f64>,
}

impl ExecTimeModel {
    /// The paper's Table IV measurements (V100, ViT-small subnet).
    pub fn paper() -> ExecTimeModel {
        ExecTimeModel {
            full_ms: vec![2.01, 2.20, 2.27, 2.74, 3.16],
            fwd_ms: vec![0.86, 1.01, 1.05, 1.20, 1.48],
        }
    }

    /// Calibrate from measured per-micro-batch-count timings.
    pub fn calibrated(full_ms: Vec<f64>, fwd_ms: Vec<f64>) -> ExecTimeModel {
        assert!(!full_ms.is_empty() && full_ms.len() == fwd_ms.len());
        ExecTimeModel { full_ms, fwd_ms }
    }

    /// Rescale both tables by a measured/modeled time ratio — the live
    /// calibration feedback: `dist::DistTrainer` measures real per-task
    /// times, derives `factor = measured / modeled` at each epoch
    /// boundary, and feeds the scaled tables back through
    /// [`ExecTimeModel::calibrated`] so the modeled makespan tracks
    /// *this host's* hardware instead of the paper's V100.
    pub fn scaled(&self, factor: f64) -> ExecTimeModel {
        assert!(
            factor.is_finite() && factor > 0.0,
            "calibration factor must be positive and finite, got {factor}"
        );
        ExecTimeModel::calibrated(
            self.full_ms.iter().map(|&t| t * factor).collect(),
            self.fwd_ms.iter().map(|&t| t * factor).collect(),
        )
    }

    fn lookup(table: &[f64], n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        if n <= table.len() {
            return table[n - 1];
        }
        // Linear extrapolation from the last two points.
        let m = table.len();
        let slope = if m >= 2 { table[m - 1] - table[m - 2] } else { table[0] };
        table[m - 1] + slope * (n - m) as f64
    }

    /// Time for one subnet to run `n` micro-batches under `op`.
    pub fn time_ms(&self, op: Op, n: usize) -> f64 {
        match op {
            Op::Full => Self::lookup(&self.full_ms, n),
            Op::ForwardOnly => Self::lookup(&self.fwd_ms, n),
            Op::Shortcut => 0.0,
        }
    }

    /// Incremental time of the `k`-th (1-based) micro-batch of `op` in a
    /// batched device row. Marginals telescope: summing them for
    /// `k = 1..=n` reproduces `time_ms(op, n)` — the execution engine
    /// charges tasks individually yet matches the batched row totals.
    pub fn marginal_ms(&self, op: Op, k: usize) -> f64 {
        if k == 0 {
            return 0.0;
        }
        self.time_ms(op, k) - self.time_ms(op, k - 1)
    }

    /// Time for a device given its schedule row (p_f count + p_o count;
    /// batched execution, as the paper measures).
    pub fn device_time_ms(&self, table: &ScheduleTable, subnet: usize) -> f64 {
        let nf = table.count_row(subnet, Op::Full);
        let no = table.count_row(subnet, Op::ForwardOnly);
        self.time_ms(Op::Full, nf) + self.time_ms(Op::ForwardOnly, no)
    }

    /// Per-device speed multiplier variant (computational heterogeneity,
    /// §IV-D: "high speed" devices run ops faster).
    pub fn device_time_scaled_ms(
        &self,
        table: &ScheduleTable,
        subnet: usize,
        speed: f64,
    ) -> f64 {
        assert!(speed > 0.0);
        self.device_time_ms(table, subnet) / speed
    }

    /// Batch makespan: the slowest device gates the synchronous step.
    pub fn makespan_ms(&self, table: &ScheduleTable) -> f64 {
        (0..table.n_subnets)
            .map(|k| self.device_time_ms(table, k))
            .fold(0.0, f64::max)
    }

    /// Average device time (the "execution time" of paper Table II when
    /// workloads are balanced: equal to makespan iff variance is 0).
    pub fn mean_device_time_ms(&self, table: &ScheduleTable) -> f64 {
        if table.n_subnets == 0 {
            return 0.0;
        }
        (0..table.n_subnets)
            .map(|k| self.device_time_ms(table, k))
            .sum::<f64>()
            / table.n_subnets as f64
    }

    /// The paper's observed forward/full ratio (≈ 0.4 across counts).
    pub fn fwd_ratio(&self, n: usize) -> f64 {
        self.time_ms(Op::ForwardOnly, n) / self.time_ms(Op::Full, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::table::ScheduleTable;

    #[test]
    fn paper_table_iv_values() {
        let m = ExecTimeModel::paper();
        assert_eq!(m.time_ms(Op::Full, 1), 2.01);
        assert_eq!(m.time_ms(Op::Full, 5), 3.16);
        assert_eq!(m.time_ms(Op::ForwardOnly, 3), 1.05);
        assert_eq!(m.time_ms(Op::Shortcut, 4), 0.0);
        assert_eq!(m.time_ms(Op::Full, 0), 0.0);
    }

    #[test]
    fn fwd_ratio_near_forty_percent() {
        let m = ExecTimeModel::paper();
        for n in 1..=5 {
            let r = m.fwd_ratio(n);
            assert!((0.35..=0.50).contains(&r), "ratio {r} at n={n}");
        }
    }

    #[test]
    fn marginals_telescope_to_batched_times() {
        let m = ExecTimeModel::paper();
        for n in 1..=8 {
            for op in [Op::Full, Op::ForwardOnly] {
                let sum: f64 = (1..=n).map(|k| m.marginal_ms(op, k)).sum();
                assert!(
                    (sum - m.time_ms(op, n)).abs() < 1e-9,
                    "op {op:?} n {n}: {sum} vs {}",
                    m.time_ms(op, n)
                );
            }
        }
        assert_eq!(m.marginal_ms(Op::Shortcut, 3), 0.0);
        assert_eq!(m.marginal_ms(Op::Full, 0), 0.0);
    }

    #[test]
    fn scaled_tables_scale_every_lookup() {
        let m = ExecTimeModel::paper();
        let s = m.scaled(2.5);
        for n in 0..=8 {
            for op in [Op::Full, Op::ForwardOnly] {
                assert!(
                    (s.time_ms(op, n) - 2.5 * m.time_ms(op, n)).abs() < 1e-9,
                    "op {op:?} n {n}"
                );
            }
            assert_eq!(s.time_ms(Op::Shortcut, n), 0.0);
        }
        // Makespans scale with the tables.
        let t = ScheduleTable::standard(3, 5);
        assert!((s.makespan_ms(&t) - 2.5 * m.makespan_ms(&t)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "calibration factor")]
    fn scaled_rejects_nonpositive_factor() {
        ExecTimeModel::paper().scaled(0.0);
    }

    #[test]
    fn extrapolates_beyond_table() {
        let m = ExecTimeModel::paper();
        let t6 = m.time_ms(Op::Full, 6);
        assert!((t6 - (3.16 + (3.16 - 2.74))).abs() < 1e-9);
        assert!(m.time_ms(Op::Full, 7) > t6);
    }

    #[test]
    fn makespan_is_max_device_time() {
        let m = ExecTimeModel::paper();
        let mut t = ScheduleTable::all(3, 5, Op::Shortcut);
        // device 0: 3 p_f; device 1: 5 p_o; device 2: idle.
        for i in 0..3 {
            t.set(0, i, Op::Full);
        }
        for i in 0..5 {
            t.set(1, i, Op::ForwardOnly);
        }
        let d0 = m.device_time_ms(&t, 0);
        let d1 = m.device_time_ms(&t, 1);
        assert_eq!(d0, 2.27);
        assert_eq!(d1, 1.48);
        assert_eq!(m.device_time_ms(&t, 2), 0.0);
        assert_eq!(m.makespan_ms(&t), d0.max(d1));
    }

    #[test]
    fn speed_scaling() {
        let m = ExecTimeModel::paper();
        let t = ScheduleTable::all(1, 2, Op::Full);
        assert!((m.device_time_scaled_ms(&t, 0, 2.0) - m.device_time_ms(&t, 0) / 2.0).abs() < 1e-12);
    }
}
