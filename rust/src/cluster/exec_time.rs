//! Per-subnet execution-time model (paper Tables II & IV).
//!
//! The paper measures how long one subnet takes to process 1..5
//! micro-batches under `p_f` and `p_o` on their V100. We reproduce the
//! *model*: a calibrated table with linear extrapolation, which the
//! cluster uses to estimate batch makespan (the slowest device gates the
//! step — the straggler effect Table II demonstrates). The table can be
//! calibrated from the paper's numbers or re-measured on this host's
//! PJRT runtime (`calibrate`).

use crate::schedule::table::{Op, ScheduleTable};

/// Milliseconds for a subnet to process n micro-batches, per op kind.
#[derive(Clone, Debug)]
pub struct ExecTimeModel {
    /// `full_ms[n-1]` = time for n micro-batches under p_f.
    full_ms: Vec<f64>,
    /// Same for p_o.
    fwd_ms: Vec<f64>,
}

impl ExecTimeModel {
    /// The paper's Table IV measurements (V100, ViT-small subnet).
    pub fn paper() -> ExecTimeModel {
        ExecTimeModel {
            full_ms: vec![2.01, 2.20, 2.27, 2.74, 3.16],
            fwd_ms: vec![0.86, 1.01, 1.05, 1.20, 1.48],
        }
    }

    /// Calibrate from measured per-micro-batch-count timings.
    pub fn calibrated(full_ms: Vec<f64>, fwd_ms: Vec<f64>) -> ExecTimeModel {
        assert!(!full_ms.is_empty() && full_ms.len() == fwd_ms.len());
        ExecTimeModel { full_ms, fwd_ms }
    }

    /// Rescale both tables by a measured/modeled time ratio — the live
    /// calibration feedback: `dist::DistTrainer` measures real per-task
    /// times, derives `factor = measured / modeled` at each epoch
    /// boundary, and feeds the scaled tables back through
    /// [`ExecTimeModel::calibrated`] so the modeled makespan tracks
    /// *this host's* hardware instead of the paper's V100.
    pub fn scaled(&self, factor: f64) -> ExecTimeModel {
        self.scaled_per_op(factor, factor)
    }

    /// Rescale the `p_f` and `p_o` tables by *separate* factors — the
    /// op-split calibration: one host (or batch shape) can be slower on
    /// full fwd+bwd passes than the paper's fwd/full ratio predicts,
    /// and a uniform factor cannot express that. [`OpCalibrator`]
    /// derives both factors from measured per-task times.
    pub fn scaled_per_op(&self, full_factor: f64, fwd_factor: f64) -> ExecTimeModel {
        for (name, f) in [("p_f", full_factor), ("p_o", fwd_factor)] {
            assert!(
                f.is_finite() && f > 0.0,
                "calibration factor must be positive and finite, got {f} for {name}"
            );
        }
        ExecTimeModel::calibrated(
            self.full_ms.iter().map(|&t| t * full_factor).collect(),
            self.fwd_ms.iter().map(|&t| t * fwd_factor).collect(),
        )
    }

    fn lookup(table: &[f64], n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        if n <= table.len() {
            return table[n - 1];
        }
        // Linear extrapolation from the last two points.
        let m = table.len();
        let slope = if m >= 2 { table[m - 1] - table[m - 2] } else { table[0] };
        table[m - 1] + slope * (n - m) as f64
    }

    /// Time for one subnet to run `n` micro-batches under `op`.
    pub fn time_ms(&self, op: Op, n: usize) -> f64 {
        match op {
            Op::Full => Self::lookup(&self.full_ms, n),
            Op::ForwardOnly => Self::lookup(&self.fwd_ms, n),
            Op::Shortcut => 0.0,
        }
    }

    /// Incremental time of the `k`-th (1-based) micro-batch of `op` in a
    /// batched device row. Marginals telescope: summing them for
    /// `k = 1..=n` reproduces `time_ms(op, n)` — the execution engine
    /// charges tasks individually yet matches the batched row totals.
    pub fn marginal_ms(&self, op: Op, k: usize) -> f64 {
        if k == 0 {
            return 0.0;
        }
        self.time_ms(op, k) - self.time_ms(op, k - 1)
    }

    /// Time for a device given its schedule row (p_f count + p_o count;
    /// batched execution, as the paper measures).
    pub fn device_time_ms(&self, table: &ScheduleTable, subnet: usize) -> f64 {
        let nf = table.count_row(subnet, Op::Full);
        let no = table.count_row(subnet, Op::ForwardOnly);
        self.time_ms(Op::Full, nf) + self.time_ms(Op::ForwardOnly, no)
    }

    /// Per-device speed multiplier variant (computational heterogeneity,
    /// §IV-D: "high speed" devices run ops faster).
    pub fn device_time_scaled_ms(
        &self,
        table: &ScheduleTable,
        subnet: usize,
        speed: f64,
    ) -> f64 {
        assert!(speed > 0.0);
        self.device_time_ms(table, subnet) / speed
    }

    /// Batch makespan: the slowest device gates the synchronous step.
    pub fn makespan_ms(&self, table: &ScheduleTable) -> f64 {
        (0..table.n_subnets)
            .map(|k| self.device_time_ms(table, k))
            .fold(0.0, f64::max)
    }

    /// Average device time (the "execution time" of paper Table II when
    /// workloads are balanced: equal to makespan iff variance is 0).
    pub fn mean_device_time_ms(&self, table: &ScheduleTable) -> f64 {
        if table.n_subnets == 0 {
            return 0.0;
        }
        (0..table.n_subnets)
            .map(|k| self.device_time_ms(table, k))
            .sum::<f64>()
            / table.n_subnets as f64
    }

    /// The paper's observed forward/full ratio (≈ 0.4 across counts).
    pub fn fwd_ratio(&self, n: usize) -> f64 {
        self.time_ms(Op::ForwardOnly, n) / self.time_ms(Op::Full, n)
    }

    /// Modeled `(p_f, p_o)` time components of micro-batch `micro`
    /// summed over every device: for each device, the marginal cost of
    /// this micro within the device's batched row (marginals telescope,
    /// so summing a device's micros reproduces its row total). This is
    /// the regressor pair the op-split calibration fits measured
    /// per-task times against.
    pub fn micro_components(&self, table: &ScheduleTable, micro: usize) -> (f64, f64) {
        let mut full = 0.0;
        let mut fwd = 0.0;
        for subnet in 0..table.n_subnets {
            let op = table.get(subnet, micro);
            if op == Op::Shortcut {
                continue;
            }
            // This micro's 1-based rank among the device's same-op
            // micros up to and including it.
            let rank = (0..=micro).filter(|&j| table.get(subnet, j) == op).count();
            match op {
                Op::Full => full += self.marginal_ms(op, rank),
                Op::ForwardOnly => fwd += self.marginal_ms(op, rank),
                Op::Shortcut => {}
            }
        }
        (full, fwd)
    }

    /// Modeled `(p_f total, p_o total)` of one device's schedule row —
    /// the pieces [`ExecTimeModel::device_time_ms`] sums. Exposed so a
    /// calibrator can re-evaluate the row (and hence the makespan)
    /// under candidate per-op factors without rebuilding tables.
    pub fn device_row_components(&self, table: &ScheduleTable, subnet: usize) -> (f64, f64) {
        let nf = table.count_row(subnet, Op::Full);
        let no = table.count_row(subnet, Op::ForwardOnly);
        (self.time_ms(Op::Full, nf), self.time_ms(Op::ForwardOnly, no))
    }
}

/// Least-squares fit of measured per-task times to the model's `p_f`
/// and `p_o` components: accumulate one observation per executed task
/// (`measured ≈ pf · full_component + po · fwd_component`), then
/// [`OpCalibrator::solve`] the 2×2 normal equations for the two
/// multiplicative factors. `dist::DistTrainer` feeds the result through
/// [`ExecTimeModel::scaled_per_op`] at every epoch boundary — the
/// per-(op) refinement of the PR 4 uniform rescale (ROADMAP follow-on).
///
/// Degenerate workloads — no `p_o` tasks at all, or every task carrying
/// the same `p_f : p_o` mix (collinear regressors) — make the split
/// unidentifiable; `solve` then returns `None` and the caller falls
/// back to the uniform ratio.
#[derive(Clone, Debug, Default)]
pub struct OpCalibrator {
    /// Normal-equation accumulators: Σff, Σfo, Σoo, Σfy, Σoy.
    sff: f64,
    sfo: f64,
    soo: f64,
    sfy: f64,
    soy: f64,
    n: usize,
}

impl OpCalibrator {
    /// Fresh accumulator.
    pub fn new() -> OpCalibrator {
        OpCalibrator::default()
    }

    /// Record one task: modeled components `(full_ms, fwd_ms)` (from
    /// [`ExecTimeModel::micro_components`] under the *current* tables)
    /// against the measured wall time. Non-finite samples (a paused
    /// host, a zero-work task) are ignored.
    pub fn observe(&mut self, full_ms: f64, fwd_ms: f64, measured_ms: f64) {
        if !(full_ms.is_finite() && fwd_ms.is_finite() && measured_ms.is_finite()) {
            return;
        }
        self.sff += full_ms * full_ms;
        self.sfo += full_ms * fwd_ms;
        self.soo += fwd_ms * fwd_ms;
        self.sfy += full_ms * measured_ms;
        self.soy += fwd_ms * measured_ms;
        self.n += 1;
    }

    /// Observations accumulated since the last reset.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether no observation has been recorded.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Solve for `(pf, po)`. `None` when the system is degenerate
    /// (fewer than 2 samples, an op with no mass, collinear mixes) or
    /// the solution is not a pair of positive finite factors — callers
    /// fall back to a uniform scale.
    pub fn solve(&self) -> Option<(f64, f64)> {
        if self.n < 2 {
            return None;
        }
        let det = self.sff * self.soo - self.sfo * self.sfo;
        // Relative conditioning guard: collinear regressors give a
        // determinant that vanishes against the product of the
        // diagonal terms.
        if det <= 1e-9 * self.sff * self.soo || det <= 0.0 {
            return None;
        }
        let pf = (self.soo * self.sfy - self.sfo * self.soy) / det;
        let po = (self.sff * self.soy - self.sfo * self.sfy) / det;
        if pf.is_finite() && po.is_finite() && pf > 0.0 && po > 0.0 {
            Some((pf, po))
        } else {
            None
        }
    }

    /// Clear the accumulators (epoch boundary).
    pub fn reset(&mut self) {
        *self = OpCalibrator::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::table::ScheduleTable;

    #[test]
    fn paper_table_iv_values() {
        let m = ExecTimeModel::paper();
        assert_eq!(m.time_ms(Op::Full, 1), 2.01);
        assert_eq!(m.time_ms(Op::Full, 5), 3.16);
        assert_eq!(m.time_ms(Op::ForwardOnly, 3), 1.05);
        assert_eq!(m.time_ms(Op::Shortcut, 4), 0.0);
        assert_eq!(m.time_ms(Op::Full, 0), 0.0);
    }

    #[test]
    fn fwd_ratio_near_forty_percent() {
        let m = ExecTimeModel::paper();
        for n in 1..=5 {
            let r = m.fwd_ratio(n);
            assert!((0.35..=0.50).contains(&r), "ratio {r} at n={n}");
        }
    }

    #[test]
    fn marginals_telescope_to_batched_times() {
        let m = ExecTimeModel::paper();
        for n in 1..=8 {
            for op in [Op::Full, Op::ForwardOnly] {
                let sum: f64 = (1..=n).map(|k| m.marginal_ms(op, k)).sum();
                assert!(
                    (sum - m.time_ms(op, n)).abs() < 1e-9,
                    "op {op:?} n {n}: {sum} vs {}",
                    m.time_ms(op, n)
                );
            }
        }
        assert_eq!(m.marginal_ms(Op::Shortcut, 3), 0.0);
        assert_eq!(m.marginal_ms(Op::Full, 0), 0.0);
    }

    #[test]
    fn scaled_tables_scale_every_lookup() {
        let m = ExecTimeModel::paper();
        let s = m.scaled(2.5);
        for n in 0..=8 {
            for op in [Op::Full, Op::ForwardOnly] {
                assert!(
                    (s.time_ms(op, n) - 2.5 * m.time_ms(op, n)).abs() < 1e-9,
                    "op {op:?} n {n}"
                );
            }
            assert_eq!(s.time_ms(Op::Shortcut, n), 0.0);
        }
        // Makespans scale with the tables.
        let t = ScheduleTable::standard(3, 5);
        assert!((s.makespan_ms(&t) - 2.5 * m.makespan_ms(&t)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "calibration factor")]
    fn scaled_rejects_nonpositive_factor() {
        ExecTimeModel::paper().scaled(0.0);
    }

    #[test]
    fn extrapolates_beyond_table() {
        let m = ExecTimeModel::paper();
        let t6 = m.time_ms(Op::Full, 6);
        assert!((t6 - (3.16 + (3.16 - 2.74))).abs() < 1e-9);
        assert!(m.time_ms(Op::Full, 7) > t6);
    }

    #[test]
    fn makespan_is_max_device_time() {
        let m = ExecTimeModel::paper();
        let mut t = ScheduleTable::all(3, 5, Op::Shortcut);
        // device 0: 3 p_f; device 1: 5 p_o; device 2: idle.
        for i in 0..3 {
            t.set(0, i, Op::Full);
        }
        for i in 0..5 {
            t.set(1, i, Op::ForwardOnly);
        }
        let d0 = m.device_time_ms(&t, 0);
        let d1 = m.device_time_ms(&t, 1);
        assert_eq!(d0, 2.27);
        assert_eq!(d1, 1.48);
        assert_eq!(m.device_time_ms(&t, 2), 0.0);
        assert_eq!(m.makespan_ms(&t), d0.max(d1));
    }

    #[test]
    fn speed_scaling() {
        let m = ExecTimeModel::paper();
        let t = ScheduleTable::all(1, 2, Op::Full);
        let scaled = m.device_time_scaled_ms(&t, 0, 2.0);
        assert!((scaled - m.device_time_ms(&t, 0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn scaled_per_op_scales_each_table_independently() {
        let m = ExecTimeModel::paper();
        let s = m.scaled_per_op(2.0, 0.5);
        for n in 1..=6 {
            assert!((s.time_ms(Op::Full, n) - 2.0 * m.time_ms(Op::Full, n)).abs() < 1e-9);
            assert!(
                (s.time_ms(Op::ForwardOnly, n) - 0.5 * m.time_ms(Op::ForwardOnly, n)).abs()
                    < 1e-9
            );
        }
        // The uniform path is the diagonal of the per-op one.
        let u = m.scaled(1.7);
        let d = m.scaled_per_op(1.7, 1.7);
        assert_eq!(u.time_ms(Op::Full, 3), d.time_ms(Op::Full, 3));
    }

    /// A mixed schedule for the component helpers: device 0 runs 2 p_f
    /// + 1 p_o, device 1 runs 3 p_o, device 2 idles.
    fn mixed_table() -> ScheduleTable {
        let mut t = ScheduleTable::all(3, 3, Op::Shortcut);
        t.set(0, 0, Op::Full);
        t.set(0, 1, Op::Full);
        t.set(0, 2, Op::ForwardOnly);
        for i in 0..3 {
            t.set(1, i, Op::ForwardOnly);
        }
        t
    }

    #[test]
    fn micro_components_telescope_to_device_rows() {
        let m = ExecTimeModel::paper();
        let t = mixed_table();
        let mut full = 0.0;
        let mut fwd = 0.0;
        for i in 0..3 {
            let (f, o) = m.micro_components(&t, i);
            full += f;
            fwd += o;
        }
        let rows: Vec<(f64, f64)> =
            (0..3).map(|d| m.device_row_components(&t, d)).collect();
        let row_full: f64 = rows.iter().map(|r| r.0).sum();
        let row_fwd: f64 = rows.iter().map(|r| r.1).sum();
        assert!((full - row_full).abs() < 1e-9, "p_f marginals must telescope");
        assert!((fwd - row_fwd).abs() < 1e-9, "p_o marginals must telescope");
        assert_eq!(rows[2], (0.0, 0.0), "idle device contributes nothing");
        // Micro 0 carries device 0's first p_f and device 1's first p_o.
        let (f0, o0) = m.micro_components(&t, 0);
        assert_eq!(f0, m.time_ms(Op::Full, 1));
        assert_eq!(o0, m.time_ms(Op::ForwardOnly, 1));
    }

    #[test]
    fn op_calibrator_converges_on_a_heterogeneous_workload() {
        // Ground truth: this "host" is 2.5x slower than the tables on
        // p_f and 0.6x on p_o. Tasks with different p_f : p_o mixes
        // (the heterogeneous workload) make both factors identifiable.
        let m = ExecTimeModel::paper();
        let t = mixed_table();
        let (true_pf, true_po) = (2.5, 0.6);
        let mut cal = OpCalibrator::new();
        assert!(cal.is_empty());
        for _ in 0..4 {
            for i in 0..3 {
                let (f, o) = m.micro_components(&t, i);
                cal.observe(f, o, true_pf * f + true_po * o);
            }
        }
        assert_eq!(cal.len(), 12);
        let (pf, po) = cal.solve().expect("well-conditioned system must solve");
        assert!((pf - true_pf).abs() < 1e-6, "p_f factor: got {pf}");
        assert!((po - true_po).abs() < 1e-6, "p_o factor: got {po}");
        cal.reset();
        assert!(cal.is_empty());
    }

    #[test]
    fn op_calibrator_rejects_degenerate_systems() {
        // All-p_f workload: the p_o column is empty — unidentifiable.
        let mut cal = OpCalibrator::new();
        for i in 1..6 {
            cal.observe(i as f64, 0.0, 2.0 * i as f64);
        }
        assert!(cal.solve().is_none(), "no p_o mass must fall back to uniform");
        // Collinear mixes: every task has the same p_f : p_o ratio.
        let mut cal = OpCalibrator::new();
        for i in 1..6 {
            let s = i as f64;
            cal.observe(2.0 * s, 1.0 * s, 5.0 * s);
        }
        assert!(cal.solve().is_none(), "collinear mixes must fall back to uniform");
        // Too few samples.
        let mut cal = OpCalibrator::new();
        cal.observe(1.0, 2.0, 3.0);
        assert!(cal.solve().is_none());
        // Non-finite observations are ignored outright.
        let mut cal = OpCalibrator::new();
        cal.observe(f64::NAN, 1.0, 1.0);
        assert!(cal.is_empty());
    }
}
