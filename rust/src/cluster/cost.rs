//! The paper's operation cost model (§IV-A).
//!
//! Measured on their V100 testbed (Table IV): forward ≈ 40% of a full
//! forward+backward, independent of micro-batch count, so
//!
//! * compute:  p_f = 1.0 full-op, p_o = 0.4, p_s = 0
//! * comm:     p_f = 1.0 (activations fwd + gradients bwd, equal sizes),
//!             p_o = 0.5, p_s = 0
//!
//! Integer *units* (full = 5, fwd = 2) keep the knapsack DP exact.

use crate::schedule::table::Op;

/// Integer-unit operation cost model (compute + communication).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// Integer units of a forward pass (default 2).
    fwd: usize,
    /// Integer units of a backward pass (default 3).
    bwd: usize,
}

impl CostModel {
    /// The paper's calibration: c_f = 0.4 * (c_f + c_b).
    pub fn paper() -> CostModel {
        CostModel { fwd: 2, bwd: 3 }
    }

    /// Custom integer calibration (c_f = fwd/(fwd+bwd)).
    pub fn new(fwd: usize, bwd: usize) -> CostModel {
        assert!(fwd > 0 && bwd > 0);
        CostModel { fwd, bwd }
    }

    /// Units of one full (fwd+bwd) op.
    pub fn full_units(&self) -> usize {
        self.fwd + self.bwd
    }

    /// Units of one forward-only op.
    pub fn fwd_units(&self) -> usize {
        self.fwd
    }

    /// Forward fraction of a full op (paper: 0.4).
    pub fn fwd_frac(&self) -> f64 {
        self.fwd as f64 / self.full_units() as f64
    }

    /// Compute units charged for an op on one micro-batch.
    pub fn compute_units(&self, op: Op) -> usize {
        match op {
            Op::Full => self.full_units(),
            Op::ForwardOnly => self.fwd,
            Op::Shortcut => 0,
        }
    }

    /// Compute cost in full-op equivalents (p_f = 1.0).
    pub fn compute_cost(&self, op: Op) -> f64 {
        self.compute_units(op) as f64 / self.full_units() as f64
    }

    /// Communication cost in full-op equivalents: a p_o device only ships
    /// activations (half the traffic), a p_s device ships nothing.
    pub fn comm_cost(&self, op: Op) -> f64 {
        match op {
            Op::Full => 1.0,
            Op::ForwardOnly => 0.5,
            Op::Shortcut => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration() {
        let c = CostModel::paper();
        assert_eq!(c.full_units(), 5);
        assert_eq!(c.fwd_units(), 2);
        assert!((c.fwd_frac() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn op_costs() {
        let c = CostModel::paper();
        assert_eq!(c.compute_units(Op::Full), 5);
        assert_eq!(c.compute_units(Op::ForwardOnly), 2);
        assert_eq!(c.compute_units(Op::Shortcut), 0);
        assert_eq!(c.compute_cost(Op::Full), 1.0);
        assert!((c.compute_cost(Op::ForwardOnly) - 0.4).abs() < 1e-12);
        assert_eq!(c.comm_cost(Op::ForwardOnly), 0.5);
        assert_eq!(c.comm_cost(Op::Shortcut), 0.0);
    }
}
