//! Parallel multi-device execution engine.
//!
//! The seed coordinator *iterated* the simulated devices serially inside
//! one thread, so the paper's balanced-workload claim was bookkeeping,
//! never concurrency. This module turns the simulated cluster into real
//! parallel workers:
//!
//! * one worker thread per simulated device (or a fixed pool, round-robin
//!   over devices), each owning a private work queue of scheduled
//!   `(subnet, micro-batch, op)` [`Task`]s;
//! * a **step barrier**: the engine dispatches one [`ScheduleTable`] per
//!   batch, every worker simulates its devices' rows independently, and
//!   per-device reports are aggregated back through channels in device
//!   order — so parallel and serial execution are bitwise identical on
//!   every deterministic output;
//! * **communication/compute overlap**: each device's simulated uplink
//!   (activations forward, gradients backward) runs as a pipeline —
//!   the comm of micro-batch *i* overlaps the compute of micro-batch
//!   *i+1*, with the NIC serializing transfers (classic two-resource
//!   pipeline model). [`DeviceReport::serial_ms`] keeps the no-overlap
//!   time so the saving is observable;
//! * straggler time is **measured for real** (`Instant` around each
//!   device's simulated work) in addition to the modeled makespan.
//!
//! Modeled quantities (compute/comm/finish times, payload checksums,
//! synthetic losses) are pure functions of `(seed, schedule)` and are
//! identical across [`ExecMode::Serial`] and [`ExecMode::Parallel`];
//! measured quantities (`measured_*`, wall clock) depend on the host and
//! are reported separately. The determinism test in `tests/engine.rs`
//! and the `engine_parallel` bench both build on [`run_synthetic`].

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use super::cost::CostModel;
use super::exec_time::ExecTimeModel;
use super::workload::WorkloadTracker;
use crate::metrics::DeviceUsage;
use crate::schedule::bilevel::BiLevel;
use crate::schedule::table::{Budget, Op, ScheduleTable, Task};
use crate::schedule::Scheduler;
use crate::scores::{Metric, ScoreBook, ScoreConfig};
use crate::util::bench::spin_for_ms;
use crate::util::rng::Rng;

/// How the simulated cluster executes one scheduled batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Reference path: devices are simulated one after another on the
    /// calling thread (the seed coordinator's behaviour).
    Serial,
    /// Devices run on worker threads. `workers == 0` spawns one worker
    /// per simulated device (the paper's placement, footnote 1);
    /// otherwise a fixed pool serves devices round-robin.
    Parallel {
        /// Worker-thread count (0 = one per device).
        workers: usize,
    },
}

impl ExecMode {
    /// Number of worker threads this mode spawns for `n_devices`.
    pub fn worker_count(&self, n_devices: usize) -> usize {
        match *self {
            ExecMode::Serial => 0,
            ExecMode::Parallel { workers: 0 } => n_devices,
            ExecMode::Parallel { workers } => workers.min(n_devices),
        }
    }

    /// Human-readable label for reports.
    pub fn label(&self) -> String {
        match *self {
            ExecMode::Serial => "serial".into(),
            ExecMode::Parallel { workers: 0 } => "parallel(per-device)".into(),
            ExecMode::Parallel { workers } => format!("parallel({workers})"),
        }
    }
}

/// Engine knobs: execution mode, the simulated communication model, and
/// how much *real* work each modeled millisecond costs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Serial reference path or parallel workers.
    pub mode: ExecMode,
    /// Simulated transfer time for one full-op's traffic (activations +
    /// gradients) in ms; `p_o` ships half, `p_s` nothing (§IV-A).
    /// 0 disables the comm simulation entirely.
    pub comm_ms_per_fullop: f64,
    /// Overlap each micro-batch's comm with later micro-batches' compute
    /// (pipeline model); `false` serializes comm after compute.
    pub overlap_comm: bool,
    /// Real busy-work per modeled millisecond (1.0 = spin for the full
    /// modeled duration; 0 = pure accounting, no spinning).
    pub time_scale: f64,
    /// Modeled bytes one full-op's traffic puts on the wire (`p_o` ships
    /// half per [`CostModel::comm_cost`], `p_s` nothing). 0 disables the
    /// byte accounting. The `dist` runtime sets this to its dense
    /// gradient-message size so the engine's *modeled* bytes line up
    /// against the *measured* serialized bytes (DESIGN.md §dist).
    pub bytes_per_fullop: u64,
    /// Seed for the deterministic per-task payloads.
    pub seed: u64,
}

impl EngineConfig {
    /// Pure accounting: no spinning, no comm simulation. This is what
    /// the [`crate::coordinator::Trainer`] uses — modeled times match
    /// the seed coordinator's `ExecTimeModel` bookkeeping exactly.
    pub fn accounting(mode: ExecMode, seed: u64) -> EngineConfig {
        EngineConfig {
            mode,
            comm_ms_per_fullop: 0.0,
            overlap_comm: true,
            time_scale: 0.0,
            bytes_per_fullop: 0,
            seed,
        }
    }

    /// Full simulation: devices spin for their modeled time and the comm
    /// pipeline is active. Used by the `engine_parallel` bench and the
    /// determinism tests' synthetic workload.
    pub fn simulation(mode: ExecMode, seed: u64) -> EngineConfig {
        EngineConfig {
            mode,
            comm_ms_per_fullop: 1.0,
            overlap_comm: true,
            time_scale: 1.0,
            bytes_per_fullop: 0,
            seed,
        }
    }
}

/// What one simulated device did during one step.
#[derive(Clone, Debug)]
pub struct DeviceReport {
    /// Device (= subnet) index.
    pub device: usize,
    /// Modeled compute time for this step (batched `ExecTimeModel` row).
    pub compute_ms: f64,
    /// Modeled communication time (sum over this device's transfers).
    pub comm_ms: f64,
    /// Modeled finish time with the configured overlap policy.
    pub finish_ms: f64,
    /// Modeled finish time with comm fully serialized after compute.
    pub serial_ms: f64,
    /// Micro-batches actually processed (`p_f` + `p_o`).
    pub processed: usize,
    /// Deterministic pseudo-gradient contribution (`p_f` tasks only).
    pub grad: f64,
    /// Deterministic activation/gradient payload checksum.
    pub checksum: u64,
    /// Modeled bytes this device put on the wire this step
    /// (`comm_cost(op) * bytes_per_fullop` per task).
    pub wire_bytes: u64,
    /// Wall-clock time this device's simulation actually took (ms).
    pub measured_ms: f64,
}

/// Aggregated outcome of one engine step (the barrier's output).
#[derive(Clone, Debug)]
pub struct StepReport {
    /// Per-device reports, sorted by device index.
    pub devices: Vec<DeviceReport>,
    /// Modeled batch makespan: the slowest device gates the step.
    pub makespan_ms: f64,
    /// Mean modeled device finish time.
    pub mean_device_ms: f64,
    /// Total modeled time saved by comm/compute overlap this step.
    pub comm_saved_ms: f64,
    /// Pseudo-gradient aggregate, reduced in device order (bit-stable).
    pub grad: f64,
    /// Payload checksum folded in device order (bit-stable).
    pub checksum: u64,
    /// Modeled bytes on the wire this step, summed over devices.
    pub wire_bytes: u64,
    /// Measured straggler: max wall-clock device time (`Instant`).
    pub measured_straggler_ms: f64,
    /// Measured wall-clock of the whole step (dispatch -> barrier).
    pub measured_wall_ms: f64,
}

impl StepReport {
    fn from_devices(devices: Vec<DeviceReport>, measured_wall_ms: f64) -> StepReport {
        let k = devices.len().max(1) as f64;
        let makespan_ms = devices.iter().map(|d| d.finish_ms).fold(0.0, f64::max);
        let mean_device_ms = devices.iter().map(|d| d.finish_ms).sum::<f64>() / k;
        let comm_saved_ms = devices.iter().map(|d| d.serial_ms - d.finish_ms).sum::<f64>();
        let grad = devices.iter().map(|d| d.grad).sum::<f64>();
        let mut checksum = 0u64;
        for d in &devices {
            checksum = checksum.rotate_left(7) ^ d.checksum;
        }
        let wire_bytes = devices.iter().map(|d| d.wire_bytes).sum();
        let measured_straggler_ms =
            devices.iter().map(|d| d.measured_ms).fold(0.0, f64::max);
        StepReport {
            devices,
            makespan_ms,
            mean_device_ms,
            comm_saved_ms,
            grad,
            checksum,
            wire_bytes,
            measured_straggler_ms,
            measured_wall_ms,
        }
    }

    /// Per-device modeled finish times, in device order.
    pub fn finish_ms(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.finish_ms).collect()
    }

    /// Per-device measured wall-clock times, in device order.
    pub fn measured_ms(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.measured_ms).collect()
    }
}

/// One worker's share of a step: the devices it simulates this batch.
struct StepJob {
    devices: Vec<DeviceWork>,
}

/// One device's row of scheduled tasks for the current batch.
struct DeviceWork {
    device: usize,
    tasks: Vec<Task>,
}

/// The parallel multi-device execution engine.
///
/// Owns the worker threads and their work queues for one simulated
/// cluster. [`Engine::execute`] is a full step barrier: it dispatches a
/// [`ScheduleTable`], blocks until every device reported, and returns
/// the aggregated [`StepReport`]. Dropping the engine shuts the workers
/// down cleanly.
pub struct Engine {
    cfg: EngineConfig,
    n_devices: usize,
    exec: ExecTimeModel,
    cost: CostModel,
    /// Per-worker work queues (empty in serial mode).
    txs: Vec<mpsc::Sender<StepJob>>,
    /// Barrier channel the workers report back on.
    rx: Option<mpsc::Receiver<DeviceReport>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl Engine {
    /// Engine over the paper's cost and execution-time models.
    pub fn new(cfg: EngineConfig, n_devices: usize) -> Engine {
        Engine::with_models(cfg, n_devices, ExecTimeModel::paper(), CostModel::paper())
    }

    /// Engine with custom models (calibrated exec-time tables, custom
    /// cost units).
    pub fn with_models(
        cfg: EngineConfig,
        n_devices: usize,
        exec: ExecTimeModel,
        cost: CostModel,
    ) -> Engine {
        assert!(n_devices > 0, "engine needs at least one device");
        let n_workers = cfg.mode.worker_count(n_devices);
        let mut txs = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        let rx = if n_workers == 0 {
            None
        } else {
            let (res_tx, res_rx) = mpsc::channel::<DeviceReport>();
            for w in 0..n_workers {
                let (tx, job_rx) = mpsc::channel::<StepJob>();
                let res = res_tx.clone();
                let exec = exec.clone();
                let worker_cfg = cfg;
                let handle = thread::Builder::new()
                    .name(format!("d2ft-worker-{w}"))
                    .spawn(move || {
                        while let Ok(job) = job_rx.recv() {
                            for dev in job.devices {
                                let rep = run_device(
                                    &exec,
                                    &cost,
                                    &worker_cfg,
                                    dev.device,
                                    &dev.tasks,
                                );
                                if res.send(rep).is_err() {
                                    return;
                                }
                            }
                        }
                    })
                    .expect("spawning engine worker");
                txs.push(tx);
                handles.push(handle);
            }
            Some(res_rx)
        };
        Engine { cfg, n_devices, exec, cost, txs, rx, handles }
    }

    /// Number of simulated devices this engine drives.
    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Number of live worker threads (0 in serial mode).
    pub fn n_workers(&self) -> usize {
        self.txs.len()
    }

    /// The engine's configuration.
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Execute one scheduled batch across all devices and block on the
    /// step barrier. Deterministic outputs are identical in serial and
    /// parallel mode (reports are re-ordered by device index before
    /// aggregation).
    pub fn execute(&mut self, table: &ScheduleTable) -> StepReport {
        assert_eq!(
            table.n_subnets, self.n_devices,
            "schedule table rows != engine devices"
        );
        let _sp = crate::obs::trace::span("model", "engine_execute");
        let t0 = Instant::now();
        let mut reports: Vec<DeviceReport> = Vec::with_capacity(self.n_devices);
        if self.txs.is_empty() {
            for k in 0..self.n_devices {
                reports.push(run_device(
                    &self.exec,
                    &self.cost,
                    &self.cfg,
                    k,
                    &table.device_tasks(k),
                ));
            }
        } else {
            let n_workers = self.txs.len();
            let mut jobs: Vec<StepJob> = (0..n_workers)
                .map(|_| StepJob { devices: Vec::new() })
                .collect();
            for k in 0..self.n_devices {
                jobs[k % n_workers]
                    .devices
                    .push(DeviceWork { device: k, tasks: table.device_tasks(k) });
            }
            for (tx, job) in self.txs.iter().zip(jobs) {
                tx.send(job).expect("engine worker queue closed");
            }
            let rx = self.rx.as_ref().expect("parallel engine has a barrier");
            for _ in 0..self.n_devices {
                reports.push(rx.recv().expect("engine worker died"));
            }
            reports.sort_by_key(|r| r.device);
        }
        StepReport::from_devices(reports, t0.elapsed().as_secs_f64() * 1e3)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Closing the work queues ends each worker's recv loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Simulate one device's row: batched compute per the exec-time model,
/// comm pipelined against compute, deterministic payloads, and real
/// (optional) busy-work so the wall clock can be measured.
fn run_device(
    exec: &ExecTimeModel,
    cost: &CostModel,
    cfg: &EngineConfig,
    device: usize,
    tasks: &[Task],
) -> DeviceReport {
    let t0 = Instant::now();
    // Occurrence count per non-shortcut op kind (p_f, p_o): the k-th op
    // of a kind costs its *marginal* batched time, so the row total
    // telescopes to the exec-time model's batched lookup.
    let mut seen = [0usize; 2];
    let mut t_compute = 0.0f64;
    let mut t_comm = 0.0f64;
    let mut compute_total = 0.0f64;
    let mut comm_total = 0.0f64;
    let mut grad = 0.0f64;
    let mut checksum = 0u64;
    let mut processed = 0usize;
    let mut wire_bytes = 0u64;
    for t in tasks {
        let slot = match t.op {
            Op::Full => 0,
            Op::ForwardOnly => 1,
            Op::Shortcut => continue, // zero cost, no payload
        };
        seen[slot] += 1;
        let c = exec.marginal_ms(t.op, seen[slot]);
        let m = cost.comm_cost(t.op) * cfg.comm_ms_per_fullop;
        compute_total += c;
        comm_total += m;
        wire_bytes += (cost.comm_cost(t.op) * cfg.bytes_per_fullop as f64).round() as u64;
        // Pipeline: this task's transfer starts when its compute is done
        // and the NIC is free; it overlaps the next tasks' compute.
        t_compute += c;
        if m > 0.0 {
            t_comm = t_comm.max(t_compute) + m;
        }
        processed += 1;
        let (g, payload) = task_payload(cfg.seed, device, t.micro, t.op);
        grad += g;
        checksum = checksum.rotate_left(1) ^ payload;
    }
    let overlapped = t_compute.max(t_comm);
    let serial_ms = compute_total + comm_total;
    let finish_ms = if cfg.overlap_comm { overlapped } else { serial_ms };
    if cfg.time_scale > 0.0 {
        spin_for_ms(finish_ms * cfg.time_scale);
    }
    DeviceReport {
        device,
        compute_ms: compute_total,
        comm_ms: comm_total,
        finish_ms,
        serial_ms,
        processed,
        grad,
        checksum,
        wire_bytes,
        measured_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

/// Deterministic per-task payload: a pseudo-gradient (full ops only) and
/// an activation checksum, both pure functions of (seed, device, micro,
/// op) so serial and parallel execution aggregate identical values.
fn task_payload(seed: u64, device: usize, micro: usize, op: Op) -> (f64, u64) {
    if op == Op::Shortcut {
        return (0.0, 0);
    }
    let mut rng = Rng::new(
        seed ^ ((device as u64) << 32)
            ^ ((micro as u64) << 8)
            ^ op.code() as u64,
    );
    let payload = rng.next_u64();
    let g = rng.next_f64() * 2.0 - 1.0;
    match op {
        Op::Full => (g, payload),
        // Forward-only ships activations but contributes no gradient.
        _ => (0.0, payload),
    }
}

// ---------------------------------------------------------------------------
// Synthetic workload: schedule + engine with no PJRT artifacts. Shared by
// the determinism test and the `engine_parallel` bench.
// ---------------------------------------------------------------------------

/// Configuration of a self-contained synthetic engine run.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticRunConfig {
    /// Simulated devices (= subnets).
    pub n_devices: usize,
    /// Micro-batches per batch.
    pub n_micro: usize,
    /// Scheduled batches to execute.
    pub batches: usize,
    /// `p_f` slots per device per batch.
    pub n_full: usize,
    /// `p_o` slots per device per batch.
    pub n_fwd: usize,
    /// Seed for scores, payloads, and the loss recurrence.
    pub seed: u64,
    /// Engine configuration (mode, comm model, spin scale).
    pub engine: EngineConfig,
}

impl SyntheticRunConfig {
    /// Paper-shaped defaults: 5 micro-batches, `3 p_f + 1 p_o`, full
    /// simulation (spinning devices + comm pipeline).
    pub fn quick(n_devices: usize, mode: ExecMode) -> SyntheticRunConfig {
        SyntheticRunConfig {
            n_devices,
            n_micro: 5,
            batches: 16,
            n_full: 3,
            n_fwd: 1,
            seed: 17,
            engine: EngineConfig::simulation(mode, 17),
        }
    }
}

/// Outcome of [`run_synthetic`]: everything except `measured_*`/`wall_s`
/// is a pure function of the config (bitwise identical across modes).
#[derive(Clone, Debug)]
pub struct SyntheticReport {
    /// Deterministic synthetic loss after each batch.
    pub loss_curve: Vec<f64>,
    /// Payload checksum folded over all batches in device order.
    pub checksum: u64,
    /// Compute fraction relative to standard fine-tuning.
    pub compute_fraction: f64,
    /// Variance of per-device compute fraction (Table I metric).
    pub workload_variance: f64,
    /// Mean modeled batch makespan (ms).
    pub mean_makespan_ms: f64,
    /// Mean modeled per-device time (ms).
    pub mean_device_ms: f64,
    /// Mean per-device utilization (busy / makespan).
    pub mean_utilization: f64,
    /// Workload imbalance: straggler over mean busy time, minus one.
    pub imbalance: f64,
    /// Mean modeled time saved per batch by comm/compute overlap (ms).
    pub comm_saved_ms: f64,
    /// Mean measured straggler time per batch (ms; host-dependent).
    pub measured_straggler_ms: f64,
    /// Measured wall-clock of the whole run (s; host-dependent).
    pub wall_s: f64,
}

/// Score book with deterministic pseudo-scores (distinct per batch).
fn synthetic_book(n_devices: usize, n_micro: usize, seed: u64) -> ScoreBook {
    let mut rng = Rng::new(seed);
    let mut book = ScoreBook::zeros(n_devices, n_micro);
    for k in 0..n_devices {
        for i in 0..n_micro {
            book.set(Metric::Fisher, k, i, rng.next_f64() * 10.0);
            book.set(Metric::GradMag, k, i, rng.next_f64() * 5.0);
            book.set(Metric::Taylor, k, i, rng.next_f64());
            book.set(Metric::WeightMag, k, i, (k + 1) as f64);
        }
    }
    book
}

/// Run a self-contained synthetic workload: D2FT bi-level scheduling
/// over pseudo-scores, executed on the engine batch by batch, with a
/// deterministic loss recurrence driven by the aggregated
/// pseudo-gradients. No artifacts or PJRT required.
pub fn run_synthetic(cfg: &SyntheticRunConfig) -> SyntheticReport {
    assert!(cfg.n_devices > 0 && cfg.batches > 0);
    let budget = Budget::uniform(cfg.n_micro, cfg.n_full, cfg.n_fwd);
    let mut sched = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    let mut engine = Engine::new(cfg.engine, cfg.n_devices);
    let mut workloads = WorkloadTracker::new(CostModel::paper(), cfg.n_devices);
    let mut usage = DeviceUsage::new(cfg.n_devices);
    let mut loss_curve = Vec::with_capacity(cfg.batches);
    let mut loss = 4.0f64;
    let mut checksum = 0u64;
    let mut makespan_sum = 0.0;
    let mut device_ms_sum = 0.0;
    let mut saved_sum = 0.0;
    let mut straggler_sum = 0.0;
    let t0 = Instant::now();
    for b in 0..cfg.batches {
        let book = synthetic_book(
            cfg.n_devices,
            cfg.n_micro,
            cfg.seed ^ (b as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let table = sched.schedule(&book, &budget);
        let rep = engine.execute(&table);
        workloads.record(&table);
        workloads.record_measured(&rep.measured_ms());
        usage.record(&rep.finish_ms());
        // Deterministic contraction: the factor stays in (0.975, 0.995),
        // so the loss decreases monotonically but depends on the grads.
        let step_grad = rep.grad / cfg.n_devices as f64;
        loss *= 0.985 + 0.01 * step_grad.tanh();
        loss_curve.push(loss);
        checksum = checksum.rotate_left(9) ^ rep.checksum;
        makespan_sum += rep.makespan_ms;
        device_ms_sum += rep.mean_device_ms;
        saved_sum += rep.comm_saved_ms;
        straggler_sum += rep.measured_straggler_ms;
    }
    let b = cfg.batches as f64;
    SyntheticReport {
        loss_curve,
        checksum,
        compute_fraction: workloads.total_compute_fraction(),
        workload_variance: workloads.workload_variance(),
        mean_makespan_ms: makespan_sum / b,
        mean_device_ms: device_ms_sum / b,
        mean_utilization: usage.mean_utilization(),
        imbalance: usage.imbalance(),
        comm_saved_ms: saved_sum / b,
        measured_straggler_ms: straggler_sum / b,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_3x5() -> ScheduleTable {
        // device 0: 3 p_f + 1 p_o; device 1: 5 p_o; device 2: idle.
        let mut t = ScheduleTable::all(3, 5, Op::Shortcut);
        for i in 0..3 {
            t.set(0, i, Op::Full);
        }
        t.set(0, 3, Op::ForwardOnly);
        for i in 0..5 {
            t.set(1, i, Op::ForwardOnly);
        }
        t
    }

    fn strip_measured(r: &StepReport) -> (Vec<u64>, u64, u64, u64) {
        let finishes = r.devices.iter().map(|d| d.finish_ms.to_bits()).collect();
        (finishes, r.makespan_ms.to_bits(), r.grad.to_bits(), r.checksum)
    }

    #[test]
    fn serial_and_parallel_steps_are_bitwise_identical() {
        let t = table_3x5();
        let mut serial = Engine::new(EngineConfig::accounting(ExecMode::Serial, 7), 3);
        let mut par =
            Engine::new(EngineConfig::accounting(ExecMode::Parallel { workers: 0 }, 7), 3);
        let a = serial.execute(&t);
        let b = par.execute(&t);
        assert_eq!(strip_measured(&a), strip_measured(&b));
    }

    #[test]
    fn accounting_matches_exec_time_model() {
        // With comm disabled, the engine's modeled times must reproduce
        // the seed coordinator's ExecTimeModel bookkeeping.
        let t = table_3x5();
        let m = ExecTimeModel::paper();
        let mut e = Engine::new(EngineConfig::accounting(ExecMode::Serial, 1), 3);
        let r = e.execute(&t);
        for k in 0..3 {
            assert!(
                (r.devices[k].finish_ms - m.device_time_ms(&t, k)).abs() < 1e-9,
                "device {k}"
            );
        }
        assert!((r.makespan_ms - m.makespan_ms(&t)).abs() < 1e-9);
        assert!((r.mean_device_ms - m.mean_device_time_ms(&t)).abs() < 1e-9);
        assert_eq!(r.comm_saved_ms, 0.0);
    }

    #[test]
    fn comm_overlap_beats_serialized_comm() {
        let t = table_3x5();
        let mut cfg = EngineConfig::simulation(ExecMode::Serial, 1);
        cfg.time_scale = 0.0; // accounting only, keep the test fast
        let mut overlapped = Engine::new(cfg, 3);
        let ro = overlapped.execute(&t);
        cfg.overlap_comm = false;
        let mut serialized = Engine::new(cfg, 3);
        let rs = serialized.execute(&t);
        // Device 0 has 4 transfers to hide behind compute.
        assert!(ro.devices[0].finish_ms < rs.devices[0].finish_ms);
        assert!(ro.comm_saved_ms > 0.0);
        assert_eq!(rs.comm_saved_ms, 0.0);
        // Overlap can never finish *later* than serialization.
        for (a, b) in ro.devices.iter().zip(&rs.devices) {
            assert!(a.finish_ms <= b.finish_ms + 1e-12);
        }
    }

    #[test]
    fn fixed_pool_covers_all_devices() {
        let t = ScheduleTable::standard(8, 5);
        let mut e =
            Engine::new(EngineConfig::accounting(ExecMode::Parallel { workers: 2 }, 3), 8);
        assert_eq!(e.n_workers(), 2);
        let r = e.execute(&t);
        assert_eq!(r.devices.len(), 8);
        for (k, d) in r.devices.iter().enumerate() {
            assert_eq!(d.device, k);
            assert_eq!(d.processed, 5);
        }
    }

    #[test]
    fn modeled_wire_bytes_follow_cost_model() {
        let t = table_3x5();
        let mut cfg = EngineConfig::accounting(ExecMode::Serial, 1);
        cfg.bytes_per_fullop = 1000;
        let r = Engine::new(cfg, 3).execute(&t);
        // device 0: 3 p_f (1.0 each) + 1 p_o (0.5) = 3500 bytes.
        assert_eq!(r.devices[0].wire_bytes, 3500);
        // device 1: 5 p_o = 2500; device 2 idle.
        assert_eq!(r.devices[1].wire_bytes, 2500);
        assert_eq!(r.devices[2].wire_bytes, 0);
        assert_eq!(r.wire_bytes, 6000);
        // Disabled by default.
        let r0 = Engine::new(EngineConfig::accounting(ExecMode::Serial, 1), 3).execute(&t);
        assert_eq!(r0.wire_bytes, 0);
    }

    #[test]
    fn payloads_depend_on_seed() {
        let t = table_3x5();
        let a = Engine::new(EngineConfig::accounting(ExecMode::Serial, 1), 3).execute(&t);
        let b = Engine::new(EngineConfig::accounting(ExecMode::Serial, 1), 3).execute(&t);
        let c = Engine::new(EngineConfig::accounting(ExecMode::Serial, 2), 3).execute(&t);
        assert_eq!(a.checksum, b.checksum);
        assert_eq!(a.grad.to_bits(), b.grad.to_bits());
        assert_ne!(a.checksum, c.checksum);
    }

    #[test]
    fn engine_repeats_across_steps() {
        // The engine itself is stateless across steps: re-executing the
        // same table yields the same deterministic report.
        let t = table_3x5();
        let mut e = Engine::new(EngineConfig::accounting(ExecMode::Parallel { workers: 3 }, 5), 3);
        let a = e.execute(&t);
        let b = e.execute(&t);
        assert_eq!(strip_measured(&a), strip_measured(&b));
    }

    #[test]
    fn synthetic_run_is_deterministic_per_mode() {
        let mut cfg = SyntheticRunConfig::quick(4, ExecMode::Serial);
        cfg.engine.time_scale = 0.0; // fast
        cfg.batches = 6;
        let a = run_synthetic(&cfg);
        let b = run_synthetic(&cfg);
        assert_eq!(
            a.loss_curve.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.loss_curve.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.checksum, b.checksum);
        // D2FT with a uniform budget balances workloads exactly.
        assert_eq!(a.workload_variance, 0.0);
        assert!(a.loss_curve.windows(2).all(|w| w[1] < w[0]), "loss must decrease");
    }

}
