//! Per-device workload accounting (paper Table I, §III-B1).
//!
//! Accumulates compute / communication cost per device over scheduled
//! batches and reports the paper's metrics: workload variance (of
//! per-device compute fraction — 0.00 for D2FT), total compute /
//! communication fractions relative to standard fine-tuning, and sample
//! (micro-batch) counts.

use super::cost::CostModel;
use crate::schedule::table::{Op, ScheduleTable};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct WorkloadTracker {
    cost: CostModel,
    n_devices: usize,
    /// Compute units accumulated per device.
    compute_units: Vec<f64>,
    /// Communication cost (full-op equivalents) per device.
    comm: Vec<f64>,
    /// Micro-batches processed (not skipped) per device.
    processed: Vec<usize>,
    /// Full-fine-tuning compute units that the same batches would cost.
    standard_units: f64,
    batches: usize,
}

impl WorkloadTracker {
    pub fn new(cost: CostModel, n_devices: usize) -> WorkloadTracker {
        WorkloadTracker {
            cost,
            n_devices,
            compute_units: vec![0.0; n_devices],
            comm: vec![0.0; n_devices],
            processed: vec![0; n_devices],
            standard_units: 0.0,
            batches: 0,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Charge one scheduled batch.
    pub fn record(&mut self, table: &ScheduleTable) {
        assert_eq!(table.n_subnets, self.n_devices, "table/device mismatch");
        for k in 0..table.n_subnets {
            for i in 0..table.n_micro {
                let op = table.get(k, i);
                self.compute_units[k] += self.cost.compute_units(op) as f64;
                self.comm[k] += self.cost.comm_cost(op);
                if op != Op::Shortcut {
                    self.processed[k] += 1;
                }
            }
        }
        self.standard_units += (table.n_micro * self.cost.full_units()) as f64;
        self.batches += 1;
    }

    /// Per-device compute fraction relative to standard fine-tuning.
    pub fn compute_fractions(&self) -> Tensor {
        let denom = self.standard_units.max(1.0);
        Tensor::from_vec(
            &[self.n_devices],
            self.compute_units.iter().map(|&u| (u / denom) as f32).collect(),
        )
    }

    /// The paper's Table I metric: variance of per-device compute
    /// fraction (0.00 when every device does identical work).
    pub fn workload_variance(&self) -> f64 {
        self.compute_fractions().variance() as f64
    }

    /// Variance of per-device *processed micro-batch counts* (the
    /// "samples assigned to subnets" phrasing of §III-B1).
    pub fn sample_count_variance(&self) -> f64 {
        if self.n_devices == 0 {
            return 0.0;
        }
        let mean =
            self.processed.iter().sum::<usize>() as f64 / self.n_devices as f64;
        self.processed
            .iter()
            .map(|&c| (c as f64 - mean) * (c as f64 - mean))
            .sum::<f64>()
            / self.n_devices as f64
    }

    /// Total compute cost as a fraction of standard fine-tuning
    /// (standard = every device runs p_f on every micro-batch).
    pub fn total_compute_fraction(&self) -> f64 {
        let denom = self.standard_units * self.n_devices as f64;
        if denom == 0.0 {
            return 0.0;
        }
        self.compute_units.iter().sum::<f64>() / denom
    }

    /// Total communication as a fraction of standard fine-tuning (every
    /// device shipping activations + gradients for every micro-batch).
    pub fn total_comm_fraction(&self) -> f64 {
        // standard comm per device = one full-op comm per micro-batch.
        let per_device_standard = self.standard_units / self.cost.full_units() as f64;
        let denom = per_device_standard * self.n_devices as f64;
        if denom == 0.0 {
            return 0.0;
        }
        self.comm.iter().sum::<f64>() / denom
    }

    pub fn batches(&self) -> usize {
        self.batches
    }

    pub fn processed_counts(&self) -> &[usize] {
        &self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn cost() -> CostModel {
        CostModel::paper()
    }

    #[test]
    fn standard_schedule_is_fraction_one() {
        let mut w = WorkloadTracker::new(cost(), 4);
        w.record(&ScheduleTable::standard(4, 5));
        assert!((w.total_compute_fraction() - 1.0).abs() < 1e-9);
        assert!((w.total_comm_fraction() - 1.0).abs() < 1e-9);
        assert_eq!(w.workload_variance(), 0.0);
    }

    #[test]
    fn paper_60pct_budget() {
        // 3 p_f + 2 p_s of 5 -> 60% compute, 60% comm, variance 0.
        let mut t = ScheduleTable::all(3, 5, Op::Shortcut);
        for k in 0..3 {
            for i in 0..3 {
                t.set(k, i, Op::Full);
            }
        }
        let mut w = WorkloadTracker::new(cost(), 3);
        w.record(&t);
        assert!((w.total_compute_fraction() - 0.6).abs() < 1e-9);
        assert!((w.total_comm_fraction() - 0.6).abs() < 1e-9);
        assert_eq!(w.workload_variance(), 0.0);
        assert_eq!(w.sample_count_variance(), 0.0);
    }

    #[test]
    fn po_costs_forty_percent_compute_half_comm() {
        let mut t = ScheduleTable::all(1, 5, Op::Shortcut);
        for i in 0..5 {
            t.set(0, i, Op::ForwardOnly);
        }
        let mut w = WorkloadTracker::new(cost(), 1);
        w.record(&t);
        assert!((w.total_compute_fraction() - 0.4).abs() < 1e-9);
        assert!((w.total_comm_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn imbalanced_schedule_has_positive_variance() {
        let mut t = ScheduleTable::all(2, 5, Op::Shortcut);
        for i in 0..5 {
            t.set(0, i, Op::Full);
        }
        let mut w = WorkloadTracker::new(cost(), 2);
        w.record(&t);
        assert!(w.workload_variance() > 0.2);
        assert!(w.sample_count_variance() > 0.0);
    }

    #[test]
    fn property_variance_zero_iff_uniform_rows() {
        check("workload-variance-uniform", 30, |g| {
            let k = g.usize_in(2, 10);
            let n = g.usize_in(1, 6);
            let n_full = g.usize_in(0, n);
            let n_fwd = g.usize_in(0, n - n_full);
            // identical rows -> variance exactly 0
            let mut t = ScheduleTable::all(k, n, Op::Shortcut);
            for dev in 0..k {
                for i in 0..n_full {
                    t.set(dev, i, Op::Full);
                }
                for i in n_full..n_full + n_fwd {
                    t.set(dev, i, Op::ForwardOnly);
                }
            }
            let mut w = WorkloadTracker::new(CostModel::paper(), k);
            w.record(&t);
            if w.workload_variance() != 0.0 {
                return Err("uniform rows must give zero variance".into());
            }
            // perturb one device -> variance > 0 (if perturbation changes cost)
            let mut rng = Rng::new(g.usize_in(0, 1 << 20) as u64);
            let dev = rng.next_below(k as u64) as usize;
            let i = rng.next_below(n as u64) as usize;
            let old = t.get(dev, i);
            let new = if old == Op::Full { Op::Shortcut } else { Op::Full };
            t.set(dev, i, new);
            let mut w2 = WorkloadTracker::new(CostModel::paper(), k);
            w2.record(&t);
            if w2.workload_variance() <= 0.0 {
                return Err("perturbed schedule must have positive variance".into());
            }
            Ok(())
        });
    }
}
