//! Per-device workload accounting (paper Table I, §III-B1).
//!
//! Accumulates compute / communication cost per device over scheduled
//! batches and reports the paper's metrics: workload variance (of
//! per-device compute fraction — 0.00 for D2FT), total compute /
//! communication fractions relative to standard fine-tuning, and sample
//! (micro-batch) counts.

use super::cost::CostModel;
use crate::metrics::DeviceUsage;
use crate::schedule::table::{Op, ScheduleTable};
use crate::tensor::Tensor;

/// Per-device cost accumulator over scheduled batches: modeled compute /
/// communication units per device, plus *measured* wall-clock busy time
/// per device when an execution engine feeds it (`record_measured`).
#[derive(Clone, Debug)]
pub struct WorkloadTracker {
    cost: CostModel,
    n_devices: usize,
    /// Compute units accumulated per device.
    compute_units: Vec<f64>,
    /// Communication cost (full-op equivalents) per device.
    comm: Vec<f64>,
    /// Micro-batches processed (not skipped) per device.
    processed: Vec<usize>,
    /// Full-fine-tuning compute units that the same batches would cost.
    standard_units: f64,
    batches: usize,
    /// Measured wall-clock busy times per device (ms), engine-fed.
    measured: DeviceUsage,
}

impl WorkloadTracker {
    /// Fresh tracker for `n_devices` devices under `cost`.
    pub fn new(cost: CostModel, n_devices: usize) -> WorkloadTracker {
        WorkloadTracker {
            cost,
            n_devices,
            compute_units: vec![0.0; n_devices],
            comm: vec![0.0; n_devices],
            processed: vec![0; n_devices],
            standard_units: 0.0,
            batches: 0,
            measured: DeviceUsage::new(n_devices),
        }
    }

    /// Number of devices tracked.
    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Charge one scheduled batch.
    pub fn record(&mut self, table: &ScheduleTable) {
        assert_eq!(table.n_subnets, self.n_devices, "table/device mismatch");
        for t in table.tasks() {
            self.compute_units[t.subnet] += self.cost.compute_units(t.op) as f64;
            self.comm[t.subnet] += self.cost.comm_cost(t.op);
            if t.op != Op::Shortcut {
                self.processed[t.subnet] += 1;
            }
        }
        self.standard_units += (table.n_micro * self.cost.full_units()) as f64;
        self.batches += 1;
    }

    /// Per-device compute fraction relative to standard fine-tuning.
    pub fn compute_fractions(&self) -> Tensor {
        let denom = self.standard_units.max(1.0);
        Tensor::from_vec(
            &[self.n_devices],
            self.compute_units.iter().map(|&u| (u / denom) as f32).collect(),
        )
    }

    /// The paper's Table I metric: variance of per-device compute
    /// fraction (0.00 when every device does identical work).
    pub fn workload_variance(&self) -> f64 {
        self.compute_fractions().variance() as f64
    }

    /// Variance of per-device *processed micro-batch counts* (the
    /// "samples assigned to subnets" phrasing of §III-B1).
    pub fn sample_count_variance(&self) -> f64 {
        if self.n_devices == 0 {
            return 0.0;
        }
        let mean =
            self.processed.iter().sum::<usize>() as f64 / self.n_devices as f64;
        self.processed
            .iter()
            .map(|&c| (c as f64 - mean) * (c as f64 - mean))
            .sum::<f64>()
            / self.n_devices as f64
    }

    /// Total compute cost as a fraction of standard fine-tuning
    /// (standard = every device runs p_f on every micro-batch).
    pub fn total_compute_fraction(&self) -> f64 {
        let denom = self.standard_units * self.n_devices as f64;
        if denom == 0.0 {
            return 0.0;
        }
        self.compute_units.iter().sum::<f64>() / denom
    }

    /// Total communication as a fraction of standard fine-tuning (every
    /// device shipping activations + gradients for every micro-batch).
    pub fn total_comm_fraction(&self) -> f64 {
        // standard comm per device = one full-op comm per micro-batch.
        let per_device_standard = self.standard_units / self.cost.full_units() as f64;
        let denom = per_device_standard * self.n_devices as f64;
        if denom == 0.0 {
            return 0.0;
        }
        self.comm.iter().sum::<f64>() / denom
    }

    /// Record one step's *measured* per-device busy times (ms), as
    /// reported by the execution engine's workers. Delegates to a
    /// [`DeviceUsage`] accumulator; the straggler — the slowest device,
    /// which gates the synchronous step — accumulates into
    /// [`WorkloadTracker::straggler_ms()`].
    pub fn record_measured(&mut self, busy_ms: &[f64]) {
        self.measured.record(busy_ms);
    }

    /// Accumulated measured busy time per device (ms).
    pub fn measured_busy_ms(&self) -> &[f64] {
        self.measured.busy_ms()
    }

    /// Total measured straggler time: the sum over recorded steps of the
    /// slowest device's wall-clock time (what a synchronous cluster
    /// actually waits for).
    pub fn straggler_ms(&self) -> f64 {
        self.measured.total_makespan_ms()
    }

    /// Steps recorded through [`WorkloadTracker::record_measured`].
    pub fn measured_steps(&self) -> usize {
        self.measured.steps()
    }

    /// The measured-time accumulator (utilization / imbalance views).
    pub fn measured(&self) -> &DeviceUsage {
        &self.measured
    }

    /// Batches recorded through [`WorkloadTracker::record`].
    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Micro-batches processed (not skipped) per device.
    pub fn processed_counts(&self) -> &[usize] {
        &self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn cost() -> CostModel {
        CostModel::paper()
    }

    #[test]
    fn standard_schedule_is_fraction_one() {
        let mut w = WorkloadTracker::new(cost(), 4);
        w.record(&ScheduleTable::standard(4, 5));
        assert!((w.total_compute_fraction() - 1.0).abs() < 1e-9);
        assert!((w.total_comm_fraction() - 1.0).abs() < 1e-9);
        assert_eq!(w.workload_variance(), 0.0);
    }

    #[test]
    fn paper_60pct_budget() {
        // 3 p_f + 2 p_s of 5 -> 60% compute, 60% comm, variance 0.
        let mut t = ScheduleTable::all(3, 5, Op::Shortcut);
        for k in 0..3 {
            for i in 0..3 {
                t.set(k, i, Op::Full);
            }
        }
        let mut w = WorkloadTracker::new(cost(), 3);
        w.record(&t);
        assert!((w.total_compute_fraction() - 0.6).abs() < 1e-9);
        assert!((w.total_comm_fraction() - 0.6).abs() < 1e-9);
        assert_eq!(w.workload_variance(), 0.0);
        assert_eq!(w.sample_count_variance(), 0.0);
    }

    #[test]
    fn po_costs_forty_percent_compute_half_comm() {
        let mut t = ScheduleTable::all(1, 5, Op::Shortcut);
        for i in 0..5 {
            t.set(0, i, Op::ForwardOnly);
        }
        let mut w = WorkloadTracker::new(cost(), 1);
        w.record(&t);
        assert!((w.total_compute_fraction() - 0.4).abs() < 1e-9);
        assert!((w.total_comm_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn imbalanced_schedule_has_positive_variance() {
        let mut t = ScheduleTable::all(2, 5, Op::Shortcut);
        for i in 0..5 {
            t.set(0, i, Op::Full);
        }
        let mut w = WorkloadTracker::new(cost(), 2);
        w.record(&t);
        assert!(w.workload_variance() > 0.2);
        assert!(w.sample_count_variance() > 0.0);
    }

    #[test]
    fn measured_tracking_accumulates_straggler() {
        let mut w = WorkloadTracker::new(cost(), 3);
        w.record_measured(&[1.0, 4.0, 2.0]);
        w.record_measured(&[3.0, 1.0, 1.0]);
        assert_eq!(w.measured_steps(), 2);
        assert_eq!(w.measured_busy_ms(), &[4.0, 5.0, 3.0]);
        // straggler = 4.0 (step 1) + 3.0 (step 2)
        assert!((w.straggler_ms() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn property_variance_zero_iff_uniform_rows() {
        check("workload-variance-uniform", 30, |g| {
            let k = g.usize_in(2, 10);
            let n = g.usize_in(1, 6);
            let n_full = g.usize_in(0, n);
            let n_fwd = g.usize_in(0, n - n_full);
            // identical rows -> variance exactly 0
            let mut t = ScheduleTable::all(k, n, Op::Shortcut);
            for dev in 0..k {
                for i in 0..n_full {
                    t.set(dev, i, Op::Full);
                }
                for i in n_full..n_full + n_fwd {
                    t.set(dev, i, Op::ForwardOnly);
                }
            }
            let mut w = WorkloadTracker::new(CostModel::paper(), k);
            w.record(&t);
            if w.workload_variance() != 0.0 {
                return Err("uniform rows must give zero variance".into());
            }
            // perturb one device -> variance > 0 (if perturbation changes cost)
            let mut rng = Rng::new(g.usize_in(0, 1 << 20) as u64);
            let dev = rng.next_below(k as u64) as usize;
            let i = rng.next_below(n as u64) as usize;
            let old = t.get(dev, i);
            let new = if old == Op::Full { Op::Shortcut } else { Op::Full };
            t.set(dev, i, new);
            let mut w2 = WorkloadTracker::new(CostModel::paper(), k);
            w2.record(&t);
            if w2.workload_variance() <= 0.0 {
                return Err("perturbed schedule must have positive variance".into());
            }
            Ok(())
        });
    }
}
