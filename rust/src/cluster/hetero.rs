//! Device heterogeneity configurations (paper §IV-D, Tables VII & VIII).
//!
//! * **Memory heterogeneity**: "large" devices host two heads + 1/3 FFN
//!   (a merged 2-head subnet), "small" devices one head + 1/6 FFN —
//!   expressed through [`crate::partition::Partition::heterogeneous`].
//! * **Computational heterogeneity**: all devices host one head, but
//!   "high speed" devices run `3 p_f + 1 p_o` per batch while "slow"
//!   devices run `2 p_f + 2 p_o` — expressed as per-device budget
//!   overrides plus a speed multiplier in the exec-time model.

use crate::partition::Partition;
use crate::runtime::ModelConfig;
use crate::schedule::table::Budget;

/// A heterogeneous cluster description.
#[derive(Clone, Debug)]
pub struct HeteroSpec {
    /// Merged 2-head subnets (memory heterogeneity); 0 = homogeneous.
    pub n_large_memory: usize,
    /// Devices with the fast budget (computational heterogeneity).
    pub n_high_speed: usize,
    /// Speed multiplier for high-speed devices (exec-time division).
    pub speed_factor: f64,
}

impl HeteroSpec {
    /// No heterogeneity: per-head partition, uniform budgets.
    pub fn homogeneous() -> HeteroSpec {
        HeteroSpec { n_large_memory: 0, n_high_speed: 0, speed_factor: 1.5 }
    }

    /// Paper Table VII rows: {9, 14, 19} large-memory devices.
    pub fn memory(n_large: usize) -> HeteroSpec {
        HeteroSpec { n_large_memory: n_large, n_high_speed: 0, speed_factor: 1.5 }
    }

    /// Paper Table VIII rows: {9, 14, 19} high-speed devices.
    pub fn compute(n_fast: usize) -> HeteroSpec {
        HeteroSpec { n_large_memory: 0, n_high_speed: n_fast, speed_factor: 1.5 }
    }

    /// Build the partition this spec induces.
    pub fn partition(&self, cfg: &ModelConfig) -> Partition {
        if self.n_large_memory > 0 {
            Partition::heterogeneous(cfg, self.n_large_memory)
        } else {
            Partition::per_head(cfg)
        }
    }

    /// Build the budget: slow devices 2 p_f + 2 p_o, fast devices
    /// 3 p_f + 1 p_o (the paper's §IV-D setting), homogeneous default
    /// `base`.
    pub fn budget(&self, base: Budget, n_devices: usize) -> Budget {
        let mut b = base;
        for k in 0..self.n_high_speed.min(n_devices) {
            b = b.with_device_override(k, 3, 1);
        }
        b
    }

    /// Per-device speed multipliers for the exec-time model.
    pub fn speeds(&self, n_devices: usize) -> Vec<f64> {
        (0..n_devices)
            .map(|k| if k < self.n_high_speed { self.speed_factor } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            img_size: 32, patch: 4, dim: 192, depth: 6, heads: 6,
            mlp_ratio: 4, classes: 196, lora_rank: 0, head_dim: 32, tokens: 65,
        }
    }

    #[test]
    fn memory_hetero_shrinks_device_count() {
        let spec = HeteroSpec::memory(9);
        let p = spec.partition(&cfg());
        p.validate().unwrap();
        assert_eq!(p.n_subnets(), 36 - 9);
        assert_eq!(p.subnets.iter().filter(|s| s.n_heads() == 2).count(), 9);
    }

    #[test]
    fn compute_hetero_overrides_budgets() {
        let spec = HeteroSpec::compute(3);
        let b = spec.budget(Budget::uniform(5, 2, 2), 10);
        assert_eq!(b.for_device(0), (3, 1));
        assert_eq!(b.for_device(2), (3, 1));
        assert_eq!(b.for_device(3), (2, 2));
        let speeds = spec.speeds(5);
        assert_eq!(speeds, vec![1.5, 1.5, 1.5, 1.0, 1.0]);
    }

    #[test]
    fn homogeneous_is_identity() {
        let spec = HeteroSpec::homogeneous();
        let p = spec.partition(&cfg());
        assert_eq!(p.n_subnets(), 36);
        let b = spec.budget(Budget::uniform(5, 2, 2), 36);
        assert_eq!(b.for_device(17), (2, 2));
    }
}
