//! Contribution scores (paper §II-A3, Table III).
//!
//! The HLO score probe emits `[L, H, 4]` per micro-batch (fisher,
//! gradient magnitude, taylor importance, weight magnitude); the
//! [`ScoreBook`] aggregates those onto a [`Partition`]'s subnets (sum
//! over the heads a subnet owns) and exposes per-(subnet, micro-batch)
//! rows to the schedulers.
//!
//! Defaults follow the paper's ablation (Table III): **weight magnitude**
//! as the backward (p_f) score, **Fisher information** as the forward
//! (p_o) score.

use crate::partition::Partition;
use crate::tensor::Tensor;

/// The four candidate metrics, in probe channel order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Fisher information (squared gradient of the log-likelihood).
    Fisher = 0,
    /// Gradient magnitude.
    GradMag = 1,
    /// First-order Taylor importance.
    Taylor = 2,
    /// Weight magnitude.
    WeightMag = 3,
}

impl Metric {
    /// Parse a CLI metric label.
    pub fn parse(s: &str) -> anyhow::Result<Metric> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fisher" => Metric::Fisher,
            "gradmag" | "grad" => Metric::GradMag,
            "taylor" => Metric::Taylor,
            "weightmag" | "weight" | "magnitude" => Metric::WeightMag,
            _ => anyhow::bail!("unknown metric {s:?} (fisher|gradmag|taylor|weightmag)"),
        })
    }

    /// The paper's display name for this metric.
    pub fn name(self) -> &'static str {
        match self {
            Metric::Fisher => "Fisher Information",
            Metric::GradMag => "Gradient Magnitude",
            Metric::Taylor => "Taylor Importance",
            Metric::WeightMag => "Weight Magnitude",
        }
    }
}

/// Which metric feeds which level of the bi-level optimization.
#[derive(Clone, Copy, Debug)]
pub struct ScoreConfig {
    /// Outer level (p_f selection) — paper default: weight magnitude.
    pub backward: Metric,
    /// Inner level (p_o selection) — paper default: Fisher information.
    pub forward: Metric,
}

impl Default for ScoreConfig {
    fn default() -> Self {
        ScoreConfig { backward: Metric::WeightMag, forward: Metric::Fisher }
    }
}

/// Per-batch contribution scores: `n_subnets x n_micro` per metric.
#[derive(Clone, Debug)]
pub struct ScoreBook {
    /// Number of subnets scored.
    pub n_subnets: usize,
    /// Micro-batches per batch.
    pub n_micro: usize,
    /// `data[metric][subnet * n_micro + micro]`
    data: [Vec<f64>; 4],
}

impl ScoreBook {
    /// All-zero book (score-free policies and tests).
    pub fn zeros(n_subnets: usize, n_micro: usize) -> ScoreBook {
        ScoreBook {
            n_subnets,
            n_micro,
            data: std::array::from_fn(|_| vec![0.0; n_subnets * n_micro]),
        }
    }

    /// Aggregate per-head probe outputs (`[L, H, 4]`, one per micro-batch)
    /// onto the partition's subnets.
    pub fn from_probes(part: &Partition, probes: &[Tensor]) -> ScoreBook {
        let n_micro = probes.len();
        let mut book = ScoreBook::zeros(part.n_subnets(), n_micro);
        for (i, probe) in probes.iter().enumerate() {
            assert_eq!(probe.shape(), &[part.depth, part.heads, 4], "probe shape");
            for (k, s) in part.subnets.iter().enumerate() {
                for m in 0..4 {
                    let sum: f64 = s
                        .heads()
                        .map(|h| probe.at(&[s.block, h, m]) as f64)
                        .sum();
                    book.data[m][k * n_micro + i] += sum;
                }
            }
        }
        book
    }

    /// Score of `(subnet, micro)` under `metric`.
    pub fn get(&self, metric: Metric, subnet: usize, micro: usize) -> f64 {
        self.data[metric as usize][subnet * self.n_micro + micro]
    }

    /// Set one score cell (tests and synthetic workloads).
    pub fn set(&mut self, metric: Metric, subnet: usize, micro: usize, v: f64) {
        self.data[metric as usize][subnet * self.n_micro + micro] = v;
    }

    /// One subnet's row for a metric (length `n_micro`).
    pub fn row(&self, metric: Metric, subnet: usize) -> &[f64] {
        &self.data[metric as usize][subnet * self.n_micro..(subnet + 1) * self.n_micro]
    }

    /// Total score per subnet (used by the dynamic-pruning baselines).
    pub fn subnet_total(&self, metric: Metric, subnet: usize) -> f64 {
        self.row(metric, subnet).iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig {
            img_size: 32, patch: 4, dim: 64, depth: 2, heads: 2,
            mlp_ratio: 4, classes: 10, lora_rank: 0, head_dim: 32, tokens: 65,
        }
    }

    fn probe(v: f32) -> Tensor {
        // [2, 2, 4] filled so channel m of head (l, h) = v + m + 10l + h.
        let mut t = Tensor::zeros(&[2, 2, 4]);
        for l in 0..2 {
            for h in 0..2 {
                for m in 0..4 {
                    t.set(&[l, h, m], v + m as f32 + 10.0 * l as f32 + h as f32);
                }
            }
        }
        t
    }

    #[test]
    fn aggregates_per_head_partition() {
        let part = Partition::per_head(&cfg());
        let book = ScoreBook::from_probes(&part, &[probe(0.0), probe(100.0)]);
        assert_eq!(book.n_subnets, 4);
        assert_eq!(book.n_micro, 2);
        // subnet 3 = (block 1, head 1); fisher channel (m=0) of probe 0:
        assert_eq!(book.get(Metric::Fisher, 3, 0), 11.0);
        assert_eq!(book.get(Metric::Fisher, 3, 1), 111.0);
        // taylor channel (m=2) of subnet 0 = (0, 0):
        assert_eq!(book.get(Metric::Taylor, 0, 0), 2.0);
    }

    #[test]
    fn aggregates_grouped_partition_by_sum() {
        let part = Partition::grouped(&cfg(), 2);
        let book = ScoreBook::from_probes(&part, &[probe(0.0)]);
        assert_eq!(book.n_subnets, 2);
        // subnet 0 covers heads {0, 1} of block 0: fisher = 0 + 1.
        assert_eq!(book.get(Metric::Fisher, 0, 0), 1.0);
        // subnet 1 covers block 1: 10 + 11.
        assert_eq!(book.get(Metric::Fisher, 1, 0), 21.0);
    }

    #[test]
    fn metric_parsing() {
        assert_eq!(Metric::parse("fisher").unwrap(), Metric::Fisher);
        assert_eq!(Metric::parse("WeightMag").unwrap(), Metric::WeightMag);
        assert!(Metric::parse("bogus").is_err());
    }

    #[test]
    fn default_config_matches_paper() {
        let c = ScoreConfig::default();
        assert_eq!(c.backward, Metric::WeightMag);
        assert_eq!(c.forward, Metric::Fisher);
    }
}
