//! Unified observability layer: tracing, metrics, and exposition.
//!
//! Three pillars, all dependency-free:
//!
//! - [`trace`] — a per-thread ring-buffer span recorder. Arm it with
//!   [`trace::set_enabled`], record with the [`span!`](crate::span)
//!   and [`instant!`](crate::instant) macros (cheap no-ops while
//!   disabled), drain everything with [`trace::drain`], and render a
//!   Chrome trace-event JSON with [`trace::chrome_trace_json`] that
//!   loads directly in Perfetto / `chrome://tracing`. Worker processes
//!   ship their buffers back to the aggregator in `TAG_TRACE` frames
//!   at epoch boundaries; the merge normalizes clocks via anchors
//!   exchanged in the Init handshake.
//! - [`metrics`] — counters, gauges, and log-bucket histograms
//!   (p50/p90/p99 without dependencies) behind a [`metrics::Registry`]
//!   of named handles. The dist trainer publishes its run stats —
//!   wire bytes, per-class socket traffic, step latency, membership —
//!   into a per-run registry that also backs the `DistReport` JSON.
//! - [`expo`] — a std-only HTTP endpoint ([`expo::MetricsServer`])
//!   serving a registry live as Prometheus text (`/metrics`) and JSON
//!   (`/json`); enabled with `--metrics-addr`.
//!
//! The whole layer is observation-only: nothing here feeds back into
//! scheduling, gradient math, or the wire encode path, so the bitwise
//! serial ≡ channel ≡ tcp ≡ ring contract is unaffected whether
//! tracing is armed or not.

pub mod expo;
pub mod metrics;
pub mod trace;

pub use expo::MetricsServer;
pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use trace::{SpanGuard, TraceBatch, WireEvent};
