//! Cross-process step tracing: per-thread ring-buffer span recording
//! with bounded memory, merged into one Chrome trace-event JSON.
//!
//! Recording is a **cheap no-op when disabled**: every recording call
//! starts with one relaxed atomic load and returns immediately unless
//! [`set_enabled`] armed the recorder (the dist trainer arms it when
//! `--trace-out` is given, and workers arm it from their `InitMsg`).
//! When enabled, each thread appends into its *own* fixed-capacity ring
//! (registered once, on first use, under a short-lived global lock), so
//! hot-path recording never contends across threads — the only other
//! party that ever touches a thread's ring is [`drain`], which runs at
//! epoch boundaries.
//!
//! Memory is bounded by construction: a ring holds at most
//! [`RING_CAPACITY`] events and overwrites the oldest beyond that,
//! counting every overwrite so the merged trace can report truncation
//! instead of silently losing history.
//!
//! ## Event model
//!
//! Three kinds, mirroring the Chrome trace-event phases the merged
//! artifact uses: a **span** (`ph: "X"`, start + duration), an
//! **instant** (`ph: "i"`), and a **counter** sample (`ph: "C"`).
//! Every event carries the recording thread's stable `tid`, and a
//! `lane` — the process-level timeline it belongs to (0 = aggregator,
//! `w + 1` = worker `w`), set per thread via [`set_lane`] so channel
//! workers (threads of the aggregator process) and TCP workers
//! (separate processes) land in the same per-worker Perfetto rows.
//!
//! ## Clocks
//!
//! Timestamps are microseconds since a process-local [`Instant`] epoch
//! ([`now_us`]). Worker clocks are normalized at the Init handshake:
//! the aggregator stamps its own anchor into the `InitMsg`, the worker
//! records the local time it decoded it, and ships the signed offset
//! with every [`TraceBatch`] — the merge maps every worker event onto
//! the aggregator timeline (transit time is treated as zero, which is
//! exact in-process and sub-millisecond on loopback).

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

/// Maximum events held per thread ring; the oldest events are
/// overwritten (and counted as truncated) beyond this.
pub const RING_CAPACITY: usize = 16384;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();

thread_local! {
    static LANE: Cell<u32> = const { Cell::new(0) };
    static TID: Cell<u32> = const { Cell::new(0) };
    static LOCAL_RING: RefCell<Option<Arc<Mutex<Ring>>>> = const { RefCell::new(None) };
}

fn epoch() -> &'static Instant {
    EPOCH.get_or_init(Instant::now)
}

fn rings() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Arm or disarm the recorder process-wide. Arming also pins the
/// process clock epoch so [`now_us`] is monotone from here on.
pub fn set_enabled(on: bool) {
    if on {
        let _ = epoch();
    }
    ENABLED.store(on, Ordering::Release);
}

/// Whether recording is currently armed (one relaxed load — this is
/// the entire disabled-path cost of every recording call).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Microseconds since the process trace epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Assign this thread's process lane (0 = aggregator, `w + 1` =
/// worker `w`). Threads record into lane 0 until told otherwise.
pub fn set_lane(lane: u32) {
    LANE.with(|l| l.set(lane));
}

fn lane() -> u32 {
    LANE.with(|l| l.get())
}

fn tid() -> u32 {
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

/// What one recorded event *is* (mirrors the Chrome phases).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// A duration span (`ph: "X"`): `dur_us` starting at the event's
    /// timestamp.
    Span {
        /// Span length in microseconds.
        dur_us: u64,
    },
    /// A point-in-time marker (`ph: "i"`).
    Instant,
    /// A sampled counter value (`ph: "C"`).
    Counter {
        /// The sampled value.
        value: f64,
    },
}

/// One recorded event. `name`/`cat` are `&'static str` so the hot
/// recording path never allocates; [`Event::to_wire`] owns them for
/// transport and merging.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Event name (e.g. `"grad_step"`).
    pub name: &'static str,
    /// Event category (e.g. `"compute"`, `"net"`, `"ring"`).
    pub cat: &'static str,
    /// Span / instant / counter.
    pub kind: EventKind,
    /// Microseconds since the recording process's trace epoch.
    pub ts_us: u64,
    /// Stable per-thread id (small integers, first-use order).
    pub tid: u32,
    /// Process lane: 0 = aggregator, `w + 1` = worker `w`.
    pub lane: u32,
}

impl Event {
    /// Owned form for transport and cross-process merging.
    pub fn to_wire(&self) -> WireEvent {
        WireEvent {
            name: self.name.to_string(),
            cat: self.cat.to_string(),
            kind: self.kind,
            ts_us: self.ts_us,
            tid: self.tid,
            lane: self.lane,
        }
    }
}

/// An [`Event`] with owned strings — what crosses the wire in a
/// `TAG_TRACE` frame and what the Chrome merge consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct WireEvent {
    /// Event name.
    pub name: String,
    /// Event category.
    pub cat: String,
    /// Span / instant / counter.
    pub kind: EventKind,
    /// Microseconds since the *recording* process's trace epoch (the
    /// merge applies the batch's clock offset).
    pub ts_us: u64,
    /// Stable per-thread id within the recording process.
    pub tid: u32,
    /// Process lane: 0 = aggregator, `w + 1` = worker `w`.
    pub lane: u32,
}

/// Everything one [`drain`] produced: the events (chronological) and
/// how many older events the rings overwrote to stay bounded.
#[derive(Clone, Debug, Default)]
pub struct TraceBatch {
    /// Drained events, ascending by timestamp.
    pub events: Vec<Event>,
    /// Events lost to ring overwrites since the previous drain.
    pub truncated: u64,
}

struct Ring {
    buf: Vec<Event>,
    /// Oldest-element index once the buffer is full (next overwrite
    /// target); 0 while still filling.
    head: usize,
    truncated: u64,
}

impl Ring {
    fn new() -> Ring {
        Ring { buf: Vec::new(), head: 0, truncated: 0 }
    }

    fn push(&mut self, e: Event) {
        if self.buf.len() < RING_CAPACITY {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % RING_CAPACITY;
            self.truncated += 1;
        }
    }

    fn drain(&mut self) -> (Vec<Event>, u64) {
        let mut out = std::mem::take(&mut self.buf);
        // Rotate a wrapped ring back to chronological order.
        if self.head > 0 && self.head <= out.len() {
            out.rotate_left(self.head);
        }
        self.head = 0;
        (out, std::mem::take(&mut self.truncated))
    }
}

fn with_local_ring(f: impl FnOnce(&mut Ring)) {
    LOCAL_RING.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let ring = Arc::new(Mutex::new(Ring::new()));
            match rings().lock() {
                Ok(mut all) => all.push(Arc::clone(&ring)),
                Err(poisoned) => poisoned.into_inner().push(Arc::clone(&ring)),
            }
            *slot = Some(ring);
        }
        let ring = slot.as_ref().expect("local ring just installed");
        match ring.lock() {
            Ok(mut g) => f(&mut g),
            Err(poisoned) => f(&mut poisoned.into_inner()),
        }
    });
}

/// Record an instant marker (no-op unless [`enabled`]).
pub fn instant(cat: &'static str, name: &'static str) {
    if !enabled() {
        return;
    }
    let e = Event {
        name,
        cat,
        kind: EventKind::Instant,
        ts_us: now_us(),
        tid: tid(),
        lane: lane(),
    };
    with_local_ring(|r| r.push(e));
}

/// Record a counter sample (no-op unless [`enabled`]).
pub fn counter(cat: &'static str, name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    let e = Event {
        name,
        cat,
        kind: EventKind::Counter { value },
        ts_us: now_us(),
        tid: tid(),
        lane: lane(),
    };
    with_local_ring(|r| r.push(e));
}

/// Open a span; the returned guard records one [`EventKind::Span`]
/// covering its lifetime when dropped. Disabled-at-open spans stay
/// no-ops for their whole life (enable/disable races cannot produce
/// half-recorded spans).
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    let armed = enabled();
    SpanGuard { name, cat, start_us: if armed { now_us() } else { 0 }, armed }
}

/// Live span handle from [`span`]; records on drop.
#[must_use = "a span guard records its duration when dropped — bind it to a variable"]
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start_us: u64,
    armed: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let e = Event {
            name: self.name,
            cat: self.cat,
            kind: EventKind::Span { dur_us: now_us().saturating_sub(self.start_us) },
            ts_us: self.start_us,
            tid: tid(),
            lane: lane(),
        };
        with_local_ring(|r| r.push(e));
    }
}

/// Open a trace span (sugar over [`crate::obs::trace::span`]); bind
/// the guard: `let _t = span!("net", "tcp_send");`.
#[macro_export]
macro_rules! span {
    ($cat:expr, $name:expr) => {
        $crate::obs::trace::span($cat, $name)
    };
}

/// Record a trace instant (sugar over [`crate::obs::trace::instant`]).
#[macro_export]
macro_rules! instant {
    ($cat:expr, $name:expr) => {
        $crate::obs::trace::instant($cat, $name)
    };
}

/// Drain every thread's ring (destructive): all events recorded since
/// the previous drain, chronological, plus the total truncation count.
/// Workers call this at epoch boundaries to ship their buffers home;
/// the aggregator calls it once more before writing the merged trace.
pub fn drain() -> TraceBatch {
    let mut batch = TraceBatch::default();
    let all = match rings().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    for ring in all.iter() {
        let (events, truncated) = match ring.lock() {
            Ok(mut g) => g.drain(),
            Err(poisoned) => poisoned.into_inner().drain(),
        };
        batch.events.extend(events);
        batch.truncated += truncated;
    }
    batch.events.sort_by_key(|e| e.ts_us);
    batch
}

/// Render merged events as a Chrome trace-event JSON document
/// (`chrome://tracing` / Perfetto "JSON" format): one `pid` lane per
/// process (aggregator = 0), `ph: "M"` metadata naming each lane, and
/// the events sorted by normalized timestamp. `truncated` lands in a
/// top-level field so a clipped trace is identifiable.
pub fn chrome_trace_json(events: &[WireEvent], truncated: u64) -> Json {
    let mut lanes: Vec<u32> = events.iter().map(|e| e.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    let mut out = Vec::with_capacity(events.len() + 2 * lanes.len());
    for &lane in &lanes {
        let label =
            if lane == 0 { "aggregator".to_string() } else { format!("worker {}", lane - 1) };
        out.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(lane as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", s(&label))])),
        ]));
        out.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_sort_index")),
            ("pid", num(lane as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("sort_index", num(lane as f64))])),
        ]));
    }
    let mut sorted: Vec<&WireEvent> = events.iter().collect();
    sorted.sort_by_key(|e| e.ts_us);
    for e in sorted {
        let mut fields = vec![
            ("name", s(&e.name)),
            ("cat", s(&e.cat)),
            ("pid", num(e.lane as f64)),
            ("tid", num(e.tid as f64)),
            ("ts", num(e.ts_us as f64)),
        ];
        match e.kind {
            EventKind::Span { dur_us } => {
                fields.push(("ph", s("X")));
                fields.push(("dur", num(dur_us as f64)));
            }
            EventKind::Instant => {
                fields.push(("ph", s("i")));
                fields.push(("s", s("t")));
            }
            EventKind::Counter { value } => {
                fields.push(("ph", s("C")));
                fields.push(("args", obj(vec![("value", num(value))])));
            }
        }
        out.push(obj(fields));
    }
    obj(vec![
        ("traceEvents", arr(out)),
        ("displayTimeUnit", s("ms")),
        ("truncatedEvents", num(truncated as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global state; tests that arm/drain it
    // serialize on this lock so the parallel test harness cannot make
    // them steal each other's events.
    pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        match LOCK.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = test_lock();
        set_enabled(false);
        let _ = drain();
        instant("t", "nothing");
        counter("t", "nope", 1.0);
        {
            let _sp = span("t", "invisible");
        }
        let batch = drain();
        assert!(batch.events.is_empty(), "disabled recorder must record nothing");
        assert_eq!(batch.truncated, 0);
    }

    #[test]
    fn spans_instants_and_counters_record_in_order() {
        let _g = test_lock();
        set_enabled(true);
        let _ = drain();
        {
            let _sp = span("cat", "outer");
            instant("cat", "mark");
            counter("cat", "gauge", 2.5);
        }
        set_enabled(false);
        let batch = drain();
        assert_eq!(batch.events.len(), 3);
        assert_eq!(batch.truncated, 0);
        // The span records at drop, so it carries the earliest ts but
        // lands last in ring order; drain sorts by ts.
        assert!(batch.events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        let names: Vec<&str> = batch.events.iter().map(|e| e.name).collect();
        assert!(names.contains(&"outer") && names.contains(&"mark") && names.contains(&"gauge"));
        let sp = batch.events.iter().find(|e| e.name == "outer").unwrap();
        assert!(matches!(sp.kind, EventKind::Span { .. }));
        let c = batch.events.iter().find(|e| e.name == "gauge").unwrap();
        assert_eq!(c.kind, EventKind::Counter { value: 2.5 });
    }

    #[test]
    fn ring_wraps_and_counts_truncation() {
        let _g = test_lock();
        set_enabled(true);
        let _ = drain();
        let extra = 100;
        for _ in 0..RING_CAPACITY + extra {
            instant("t", "tick");
        }
        set_enabled(false);
        let batch = drain();
        assert_eq!(batch.events.len(), RING_CAPACITY, "ring must stay bounded");
        assert_eq!(batch.truncated as usize, extra, "overwrites must be counted");
        assert!(
            batch.events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us),
            "a wrapped ring must drain chronologically"
        );
        // The drained window is the *newest* RING_CAPACITY events.
        let empty = drain();
        assert!(empty.events.is_empty());
    }

    #[test]
    fn lanes_tag_events_per_thread() {
        let _g = test_lock();
        set_enabled(true);
        let _ = drain();
        instant("t", "agg_side");
        std::thread::spawn(|| {
            set_lane(3);
            instant("t", "worker_side");
        })
        .join()
        .unwrap();
        set_enabled(false);
        let batch = drain();
        let agg = batch.events.iter().find(|e| e.name == "agg_side").unwrap();
        let wrk = batch.events.iter().find(|e| e.name == "worker_side").unwrap();
        assert_eq!(agg.lane, 0);
        assert_eq!(wrk.lane, 3);
        assert_ne!(agg.tid, wrk.tid, "threads must get distinct tids");
    }

    #[test]
    fn chrome_json_shape_holds() {
        let events = vec![
            Event {
                name: "grad_step",
                cat: "compute",
                kind: EventKind::Span { dur_us: 120 },
                ts_us: 10,
                tid: 1,
                lane: 1,
            }
            .to_wire(),
            Event {
                name: "evict",
                cat: "ctrl",
                kind: EventKind::Instant,
                ts_us: 40,
                tid: 2,
                lane: 0,
            }
            .to_wire(),
        ];
        let doc = chrome_trace_json(&events, 7);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 lanes x 2 metadata + 2 events.
        assert_eq!(evs.len(), 6);
        assert_eq!(back.get("truncatedEvents").unwrap().as_usize().unwrap(), 7);
        let span_ev = evs
            .iter()
            .find(|e| e.str_at("name").map(|n| n == "grad_step").unwrap_or(false))
            .unwrap();
        assert_eq!(span_ev.str_at("ph").unwrap(), "X");
        assert_eq!(span_ev.usize_at("dur").unwrap(), 120);
        assert_eq!(span_ev.usize_at("pid").unwrap(), 1);
        let names: Vec<String> = evs
            .iter()
            .filter(|e| e.str_at("ph").map(|p| p == "M").unwrap_or(false))
            .filter(|e| e.str_at("name").map(|n| n == "process_name").unwrap_or(false))
            .map(|e| e.get("args").unwrap().str_at("name").unwrap())
            .collect();
        assert!(names.contains(&"aggregator".to_string()));
        assert!(names.contains(&"worker 0".to_string()));
    }
}
