//! Live metrics exposition: a tiny std-only HTTP endpoint serving a
//! [`Registry`](super::metrics::Registry) as Prometheus text
//! (`GET /metrics`, also at `/`) or a JSON dump (`GET /json`).
//!
//! One accept thread, one short-lived handler per connection,
//! `HTTP/1.0` + `Connection: close` semantics — enough for a scraper
//! or `curl`, with zero dependencies and no interference with the
//! training hot path (the registry is read via atomic loads and one
//! brief map lock per render).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::Result;

use super::metrics::Registry;

/// Handle for a running exposition server. Dropping it (or calling
/// [`stop`](MetricsServer::stop)) shuts the accept loop down.
#[derive(Debug)]
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`; port 0 picks an ephemeral
    /// port) and serve `reg` until stopped.
    pub fn start(addr: &str, reg: Arc<Registry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("metrics endpoint bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("d2ft-metrics-http".into())
            .spawn(move || accept_loop(listener, reg, stop2))?;
        Ok(MetricsServer { addr: local, stop, join: Some(join) })
    }

    /// The bound address (useful when started on port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the server thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, reg: Arc<Registry>, stop: Arc<AtomicBool>) {
    loop {
        let conn = listener.accept();
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match conn {
            Ok((stream, _)) => {
                // Serve inline: requests are single-line GETs and the
                // render is microseconds; no per-connection thread.
                let _ = handle_conn(stream, &reg);
            }
            Err(_) => return,
        }
    }
}

fn handle_conn(mut stream: TcpStream, reg: &Registry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let path = req
        .lines()
        .next()
        .and_then(|line| line.split_whitespace().nth(1))
        .unwrap_or("/");
    let (status, ctype, body) = if path == "/json" {
        (
            "200 OK",
            "application/json",
            reg.to_json().to_string_pretty(),
        )
    } else if path == "/" || path == "/metrics" {
        (
            "200 OK",
            "text/plain; version=0.0.4",
            reg.render_prometheus(),
        )
    } else {
        ("404 Not Found", "text/plain", format!("no such path: {path}\n"))
    };
    let resp = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).expect("read response");
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        (head.to_string(), body.to_string())
    }

    #[test]
    fn scrape_parses_as_prometheus_and_json() {
        let reg = Arc::new(Registry::new());
        reg.inc("d2ft_wire_up_bytes_total", 1234);
        reg.set("d2ft_workers_live", 4.0);
        reg.observe("d2ft_step_latency_ms", 12.5);
        let server = MetricsServer::start("127.0.0.1:0", Arc::clone(&reg)).expect("start");
        let addr = server.addr();

        let (head, body) = http_get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.0 200"), "{head}");
        assert!(head.contains("text/plain"), "{head}");
        assert!(body.contains("d2ft_wire_up_bytes_total 1234"), "{body}");
        assert!(body.contains("d2ft_workers_live 4"), "{body}");
        assert!(body.contains("d2ft_step_latency_ms_count 1"), "{body}");
        for line in body.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, val) = line.rsplit_once(' ').expect("metric line");
            val.parse::<f64>().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        }

        // Live update is visible on the next scrape.
        reg.inc("d2ft_wire_up_bytes_total", 1);
        let (_, body2) = http_get(addr, "/metrics");
        assert!(body2.contains("d2ft_wire_up_bytes_total 1235"), "{body2}");

        let (jhead, jbody) = http_get(addr, "/json");
        assert!(jhead.contains("application/json"), "{jhead}");
        let doc = Json::parse(&jbody).expect("json dump parses");
        assert_eq!(
            doc.get("counters").unwrap().usize_at("d2ft_wire_up_bytes_total").unwrap(),
            1235
        );

        let (nf, _) = http_get(addr, "/nope");
        assert!(nf.starts_with("HTTP/1.0 404"), "{nf}");

        drop(server); // stop + join must not hang
    }
}
