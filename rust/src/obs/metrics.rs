//! Metrics registry: counters, gauges, and log-bucket histograms with
//! dependency-free p50/p90/p99, rendered as Prometheus text or JSON.
//!
//! A [`Registry`] is an instantiable, thread-safe name → metric map.
//! The dist trainer owns one per run (so parallel test runs never mix
//! values) and publishes into it at epoch boundaries and report time;
//! the `--metrics-addr` HTTP endpoint ([`super::expo`]) serves the
//! same instance live. A process-wide [`global`] registry exists for
//! ad-hoc counters outside any run.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`s shared
//! out of the registry: grab one once and update it lock-free on the
//! hot path; the registry lock is only taken on lookup and render.
//!
//! Naming: keys are Prometheus metric names, optionally with a literal
//! label set appended (`d2ft_socket_class_sent_bytes_total{class="grad-up"}`).
//! The renderer groups keys by base name so labeled series share one
//! `# TYPE` header.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::{num, obj, Json};

/// Monotone counter (u64).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `d`.
    pub fn inc(&self, d: u64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite with an absolute value (for counters mirrored from an
    /// external accumulator like `WireStats` — publishing a snapshot
    /// must be idempotent, not additive).
    pub fn store(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
}

/// Instantaneous value (f64, stored as bits in an atomic).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge(AtomicU64::new(0.0f64.to_bits()))
    }
}

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of log-spaced histogram buckets.
pub const HIST_BUCKETS: usize = 64;

/// Smallest power-of-two bucket exponent: bucket `i` spans
/// `[2^(i + HIST_MIN_EXP), 2^(i + 1 + HIST_MIN_EXP))`, so bucket 0
/// absorbs everything below ~1 µs (in ms units) and the top bucket
/// absorbs every overflow.
pub const HIST_MIN_EXP: i32 = -21;

/// Lock-free log-bucket histogram: power-of-two buckets over f64
/// samples, with exact count/sum/min/max. Percentiles come from the
/// bucket upper bounds clamped to the observed [min, max], so a
/// one-sample histogram reports that sample at every quantile and an
/// overflowing sample reports the true max rather than a bucket bound.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

fn f64_update(cell: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = f(f64::from_bits(cur)).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }
}

impl Histogram {
    /// Bucket index for a sample (0 for non-positive or tiny values,
    /// the top bucket for anything beyond the covered range).
    pub fn bucket_index(v: f64) -> usize {
        if v <= 0.0 || v.is_nan() {
            return 0;
        }
        let e = v.log2().floor() as i64 - HIST_MIN_EXP as i64;
        e.clamp(0, HIST_BUCKETS as i64 - 1) as usize
    }

    /// Record one sample. NaN samples are ignored (a poisoned timing
    /// must not wedge min/max forever).
    pub fn observe(&self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        f64_update(&self.sum_bits, |s| s + v);
        f64_update(&self.min_bits, |m| m.min(v));
        f64_update(&self.max_bits, |m| m.max(v));
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Smallest sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        let m = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }

    /// Largest sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        let m = f64::from_bits(self.max_bits.load(Ordering::Relaxed));
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }

    /// Quantile estimate for `q` in `[0, 1]`: the upper bound of the
    /// bucket where the cumulative count crosses `q`, clamped to the
    /// observed `[min, max]`. Empty histograms report 0.0.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                let upper = 2.0f64.powi(i as i32 + 1 + HIST_MIN_EXP);
                return upper.clamp(self.min(), self.max());
            }
        }
        self.max()
    }
}

#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A thread-safe name → metric map; see the module docs.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Get-or-create the counter `name`. A name registered under a
    /// different kind is replaced (last writer wins; the old handle
    /// keeps working but is no longer rendered).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.lock();
        if let Some(Metric::Counter(c)) = m.get(name) {
            return Arc::clone(c);
        }
        let c = Arc::new(Counter::default());
        m.insert(name.to_string(), Metric::Counter(Arc::clone(&c)));
        c
    }

    /// Get-or-create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.lock();
        if let Some(Metric::Gauge(g)) = m.get(name) {
            return Arc::clone(g);
        }
        let g = Arc::new(Gauge::default());
        m.insert(name.to_string(), Metric::Gauge(Arc::clone(&g)));
        g
    }

    /// Get-or-create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.lock();
        if let Some(Metric::Histogram(h)) = m.get(name) {
            return Arc::clone(h);
        }
        let h = Arc::new(Histogram::default());
        m.insert(name.to_string(), Metric::Histogram(Arc::clone(&h)));
        h
    }

    /// Convenience: add `d` to counter `name`.
    pub fn inc(&self, name: &str, d: u64) {
        self.counter(name).inc(d);
    }

    /// Convenience: overwrite counter `name` with a snapshot value.
    pub fn store(&self, name: &str, v: u64) {
        self.counter(name).store(v);
    }

    /// Convenience: set gauge `name`.
    pub fn set(&self, name: &str, v: f64) {
        self.gauge(name).set(v);
    }

    /// Convenience: record a histogram sample under `name`.
    pub fn observe(&self, name: &str, v: f64) {
        self.histogram(name).observe(v);
    }

    /// Read counter `name` back (None if absent or a different kind).
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        match self.lock().get(name) {
            Some(Metric::Counter(c)) => Some(c.get()),
            _ => None,
        }
    }

    /// Read gauge `name` back (None if absent or a different kind).
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        match self.lock().get(name) {
            Some(Metric::Gauge(g)) => Some(g.get()),
            _ => None,
        }
    }

    /// Render every metric in the Prometheus text exposition format.
    /// Histograms render as summaries (p50/p90/p99 quantile series
    /// plus `_count` and `_sum`).
    pub fn render_prometheus(&self) -> String {
        let snapshot: Vec<(String, Metric)> =
            self.lock().iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        let mut out = String::new();
        let mut last_base = String::new();
        for (name, metric) in &snapshot {
            let base = name.split('{').next().unwrap_or(name).to_string();
            let fresh_base = base != last_base;
            match metric {
                Metric::Counter(c) => {
                    if fresh_base {
                        out.push_str(&format!("# TYPE {base} counter\n"));
                    }
                    out.push_str(&format!("{name} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    if fresh_base {
                        out.push_str(&format!("# TYPE {base} gauge\n"));
                    }
                    out.push_str(&format!("{name} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    if fresh_base {
                        out.push_str(&format!("# TYPE {base} summary\n"));
                    }
                    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                        out.push_str(&format!(
                            "{base}{{quantile=\"{label}\"}} {}\n",
                            h.percentile(q)
                        ));
                    }
                    out.push_str(&format!("{base}_count {}\n", h.count()));
                    out.push_str(&format!("{base}_sum {}\n", h.sum()));
                }
            }
            last_base = base;
        }
        out
    }

    /// Render every metric as one JSON object (the `/json` dump).
    pub fn to_json(&self) -> Json {
        let snapshot: Vec<(String, Metric)> =
            self.lock().iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        for (name, metric) in &snapshot {
            match metric {
                Metric::Counter(c) => {
                    counters.insert(name.clone(), num(c.get() as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(name.clone(), num(g.get()));
                }
                Metric::Histogram(h) => {
                    hists.insert(
                        name.clone(),
                        obj(vec![
                            ("count", num(h.count() as f64)),
                            ("sum", num(h.sum())),
                            ("min", num(h.min())),
                            ("max", num(h.max())),
                            ("p50", num(h.percentile(0.5))),
                            ("p90", num(h.percentile(0.9))),
                            ("p99", num(h.percentile(0.99))),
                        ]),
                    );
                }
            }
        }
        obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }
}

/// The process-wide default registry (ad-hoc counters outside any
/// run's private registry).
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_round_trip() {
        let r = Registry::new();
        r.inc("a_total", 3);
        r.inc("a_total", 4);
        assert_eq!(r.counter_value("a_total"), Some(7));
        r.store("a_total", 5);
        assert_eq!(r.counter_value("a_total"), Some(5));
        r.set("g", 2.5);
        assert_eq!(r.gauge_value("g"), Some(2.5));
        assert_eq!(r.counter_value("missing"), None);
        assert_eq!(r.gauge_value("a_total"), None, "kind mismatch reads as absent");
    }

    #[test]
    fn histogram_empty_is_zero_everywhere() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
    }

    #[test]
    fn histogram_one_sample_reports_it_at_every_quantile() {
        let h = Histogram::default();
        h.observe(5.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum(), 5.0);
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.percentile(q), 5.0, "q={q}");
        }
    }

    #[test]
    fn histogram_overflow_bucket_clamps_to_observed_max() {
        let h = Histogram::default();
        // Far beyond the covered range: lands in the top bucket, whose
        // upper bound would be astronomically large — the quantile must
        // clamp to the true max instead.
        h.observe(1.0e30);
        assert_eq!(Histogram::bucket_index(1.0e30), HIST_BUCKETS - 1);
        assert_eq!(h.percentile(0.99), 1.0e30);
        // Non-positive and tiny samples land in bucket 0; quantiles
        // stay inside the observed [min, max].
        let h = Histogram::default();
        h.observe(-3.0);
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(-3.0), 0);
        assert_eq!(h.percentile(0.5), -3.0, "single negative sample reports itself");
        h.observe(0.0);
        assert_eq!(h.count(), 2);
        let p = h.percentile(0.5);
        assert!((-3.0..=0.0).contains(&p), "quantile inside [min, max], got {p}");
        // NaN is ignored outright.
        h.observe(f64::NAN);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn histogram_percentiles_order_sensibly() {
        let h = Histogram::default();
        // 100 samples spread over two decades.
        for i in 1..=100u32 {
            h.observe(i as f64);
        }
        let (p50, p90, p99) = (h.percentile(0.5), h.percentile(0.9), h.percentile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!((32.0..=100.0).contains(&p50), "p50 bucket bound, got {p50}");
        assert!(p99 <= 100.0, "clamped to max, got {p99}");
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 5050.0);
    }

    #[test]
    fn concurrent_writers_lose_nothing() {
        let r = Arc::new(Registry::new());
        let threads = 8;
        let per = 1000;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let c = r.counter("hits_total");
                    let h = r.histogram("lat_ms");
                    for i in 0..per {
                        c.inc(1);
                        h.observe((t * per + i) as f64 % 17.0 + 0.5);
                        r.set("last", i as f64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter_value("hits_total"), Some((threads * per) as u64));
        let h = r.histogram("lat_ms");
        assert_eq!(h.count(), (threads * per) as u64);
        let expect: f64 =
            (0..threads * per).map(|k| (k % 17) as f64 + 0.5).sum();
        assert!((h.sum() - expect).abs() < 1e-6, "atomic f64 sum drifted: {}", h.sum());
        assert!(r.gauge_value("last").is_some());
    }

    #[test]
    fn prometheus_text_renders_and_groups_labels() {
        let r = Registry::new();
        r.inc("d2ft_bytes_total{class=\"grad-up\"}", 10);
        r.inc("d2ft_bytes_total{class=\"ring\"}", 20);
        r.set("d2ft_workers_live", 4.0);
        let h = r.histogram("d2ft_step_latency_ms");
        h.observe(12.0);
        h.observe(15.0);
        let text = r.render_prometheus();
        assert_eq!(
            text.matches("# TYPE d2ft_bytes_total counter").count(),
            1,
            "labeled series share one TYPE header:\n{text}"
        );
        assert!(text.contains("d2ft_bytes_total{class=\"grad-up\"} 10"), "{text}");
        assert!(text.contains("d2ft_bytes_total{class=\"ring\"} 20"), "{text}");
        assert!(text.contains("# TYPE d2ft_workers_live gauge"), "{text}");
        assert!(text.contains("d2ft_step_latency_ms{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("d2ft_step_latency_ms_count 2"), "{text}");
        // Every non-comment line is "name[{labels}] value" with a
        // float-parseable value.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, val) = line.rsplit_once(' ').expect("line has a value");
            val.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        }
    }

    #[test]
    fn json_dump_mirrors_the_registry() {
        let r = Registry::new();
        r.inc("c_total", 2);
        r.set("g", 1.25);
        r.observe("h_ms", 3.0);
        let doc = r.to_json();
        assert_eq!(doc.get("counters").unwrap().usize_at("c_total").unwrap(), 2);
        assert_eq!(doc.get("gauges").unwrap().get("g").unwrap().as_f64().unwrap(), 1.25);
        let h = doc.get("histograms").unwrap().get("h_ms").unwrap();
        assert_eq!(h.usize_at("count").unwrap(), 1);
        assert_eq!(h.get("p50").unwrap().as_f64().unwrap(), 3.0);
    }
}
