//! # D2FT — Distributed Dynamic Fine-Tuning
//!
//! Rust + JAX + Pallas reproduction of *"You Don't Need All Attentions:
//! Distributed Dynamic Fine-Tuning for Foundation Models"* (CS.LG 2025).
//!
//! D2FT fine-tunes a partitioned Vision Transformer across `K` devices.
//! Every (subnet, micro-batch) pair is scheduled one of three operations —
//! full (`p_f`), forward-only (`p_o`), shortcut (`p_s`) — by a bi-level
//! knapsack DP over per-subnet *contribution scores*, which cuts ~40% of
//! training compute and ~50% of communication at a 1–2% accuracy cost
//! while keeping per-device workloads exactly balanced.
//!
//! Architecture (three layers; Python never on the training path):
//!
//! * **L3 (this crate)** — partitioning, contribution scores, the
//!   scheduling algorithms (paper Algorithms 1 & 2 plus all baselines), a
//!   simulated K-device cluster with the paper's cost/time model, the
//!   training coordinator, metrics, and the experiment harness that
//!   regenerates every table and figure.
//! * **L2** — the masked ViT fwd/bwd + SGD trainstep. The default
//!   [`backend::native`] implementation is pure Rust on
//!   [`tensor::Tensor`]; the optional `xla` feature swaps in the
//!   original JAX programs AOT-lowered to HLO text (`artifacts/`).
//! * **L1** — Pallas kernels (per-head masked attention, masked LoRA
//!   deltas) called from the JAX L2 and lowered into the same HLO
//!   (XLA path only; the native backend fuses the same masking into
//!   its attention loop).
//!
//! The [`backend`] module is the seam: the [`coordinator`] drives any
//! [`backend::Backend`] end-to-end, and the simulated cluster executes
//! each scheduled batch on the parallel multi-device engine
//! ([`cluster::Engine`] — one worker thread per device, step barrier,
//! comm/compute overlap; `--serial` keeps the bitwise-identical
//! reference path). The `dist` module (feature `native`) goes one step
//! further: live worker replicas execute the scheduled gradient
//! computations for real and exchange *masked* serialized gradients, so
//! the paper's communication savings are measured in bytes rather than
//! modeled — while staying bitwise identical to the serial trainer.
//! See `DESIGN.md` for the full system inventory, backend contract,
//! engine and dist dataflows, and per-experiment index.

#![warn(missing_docs)]
// CI gates on `clippy -- -D warnings`. These three style lints are
// allowed crate-wide: the hand-rolled tensor/linalg kernels and the
// schedule DP index several parallel arrays in lockstep, where
// index-based loops are the clearest (and sometimes the only bitwise-
// order-preserving) formulation, and the dist worker plumbing threads
// its full context explicitly rather than bundling ad-hoc structs.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments
)]

pub mod backend;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
#[cfg(feature = "native")]
pub mod dist;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod scores;
#[cfg(feature = "native")]
pub mod serve;
pub mod tensor;
pub mod util;

/// Crate-wide result type (anyhow-based, matching the `xla` crate's
/// error style at the boundary).
pub type Result<T> = anyhow::Result<T>;
