//! Training/eval metrics and report formatting (markdown tables that
//! mirror the paper's tables; consumed by EXPERIMENTS.md).

/// Online loss/accuracy accumulator.
#[derive(Clone, Debug, Default)]
pub struct Meter {
    n: usize,
    loss_sum: f64,
    correct: f64,
    total: f64,
}

impl Meter {
    /// Empty accumulator.
    pub fn new() -> Meter {
        Meter::default()
    }

    /// Record one step's loss and correct count over `batch` examples.
    pub fn push(&mut self, loss: f32, n_correct: f32, batch: usize) {
        self.n += 1;
        self.loss_sum += loss as f64;
        self.correct += n_correct as f64;
        self.total += batch as f64;
    }

    /// Mean loss over all pushed steps.
    pub fn mean_loss(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.loss_sum / self.n as f64
        }
    }

    /// Top-1 accuracy over all pushed examples.
    pub fn top1(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            self.correct / self.total
        }
    }

    /// Number of steps pushed.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Clear all accumulated state.
    pub fn reset(&mut self) {
        *self = Meter::default();
    }
}

/// Per-device utilization / imbalance accumulator, fed per step with the
/// devices' busy times (modeled or measured) by the execution engine.
///
/// Utilization of device `k` is its busy time divided by the total
/// makespan (what fraction of each synchronous step the device actually
/// worked); imbalance is the straggler's busy time over the mean busy
/// time, minus one (0 = perfectly balanced — the paper's Table I claim
/// made observable at runtime).
#[derive(Clone, Debug)]
pub struct DeviceUsage {
    busy_ms: Vec<f64>,
    makespan_ms: f64,
    steps: usize,
}

impl DeviceUsage {
    /// Tracker for `n_devices` devices.
    pub fn new(n_devices: usize) -> DeviceUsage {
        DeviceUsage { busy_ms: vec![0.0; n_devices], makespan_ms: 0.0, steps: 0 }
    }

    /// Record one step's per-device busy times; the step's makespan is
    /// the slowest device.
    pub fn record(&mut self, busy_ms: &[f64]) {
        assert_eq!(busy_ms.len(), self.busy_ms.len(), "device count mismatch");
        for (acc, &b) in self.busy_ms.iter_mut().zip(busy_ms) {
            *acc += b;
        }
        self.makespan_ms += busy_ms.iter().copied().fold(0.0, f64::max);
        self.steps += 1;
    }

    /// Number of devices tracked.
    pub fn n_devices(&self) -> usize {
        self.busy_ms.len()
    }

    /// Steps recorded so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Accumulated busy time per device (ms).
    pub fn busy_ms(&self) -> &[f64] {
        &self.busy_ms
    }

    /// Accumulated makespan: the sum over steps of the slowest device's
    /// busy time — what a synchronous cluster actually waits for.
    pub fn total_makespan_ms(&self) -> f64 {
        self.makespan_ms
    }

    /// Per-device utilization: busy time / accumulated makespan.
    pub fn utilization(&self) -> Vec<f64> {
        if self.makespan_ms <= 0.0 {
            return vec![0.0; self.busy_ms.len()];
        }
        self.busy_ms.iter().map(|&b| b / self.makespan_ms).collect()
    }

    /// Mean device utilization (1.0 = every device busy for the whole
    /// makespan of every step).
    pub fn mean_utilization(&self) -> f64 {
        let u = self.utilization();
        if u.is_empty() {
            return 0.0;
        }
        u.iter().sum::<f64>() / u.len() as f64
    }

    /// Straggler busy time over mean busy time, minus one (0 = balanced).
    pub fn imbalance(&self) -> f64 {
        if self.busy_ms.is_empty() {
            return 0.0;
        }
        let mean = self.busy_ms.iter().sum::<f64>() / self.busy_ms.len() as f64;
        if mean <= 0.0 {
            return 0.0;
        }
        let max = self.busy_ms.iter().copied().fold(0.0, f64::max);
        max / mean - 1.0
    }
}

/// Simple exponential moving average for loss curves.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// EMA with smoothing factor `alpha` in `[0, 1]`.
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    /// Fold in a sample and return the updated average.
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    /// Current average (None before the first push).
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Markdown table writer with aligned columns.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render as an aligned markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a fraction as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Human-readable byte count (B/KiB/MiB/GiB auto-scaled) — used by the
/// `dist` runtime's bytes-on-the-wire reports.
///
/// Unit selection accounts for display rounding: a value whose *rounded*
/// rendering would reach 1024 of its unit (e.g. 1023.96 KiB at one
/// decimal) is promoted to the next unit instead of printing the
/// nonsensical "1024.0KiB".
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let bf = b as f64;
    // Promotion thresholds are rounding-aware: KiB prints one decimal
    // (rounds up to 1024.0 from 1023.95), MiB prints two (from
    // 1023.995); bytes are exact integers.
    if bf < KIB {
        format!("{b}B")
    } else if bf / KIB < 1023.95 {
        format!("{:.1}KiB", bf / KIB)
    } else if bf / (KIB * KIB) < 1023.995 {
        format!("{:.2}MiB", bf / (KIB * KIB))
    } else {
        format!("{:.2}GiB", bf / (KIB * KIB * KIB))
    }
}

/// Relative drift of a modeled quantity against its measurement:
/// `|modeled - measured| / measured` (0 when the measurement is empty).
/// The dist runtime reports this for modeled-vs-measured batch makespan
/// after feeding measured times into `ExecTimeModel::calibrated`.
pub fn rel_drift(modeled: f64, measured: f64) -> f64 {
    if measured <= 0.0 {
        return 0.0;
    }
    (modeled - measured).abs() / measured
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates() {
        let mut m = Meter::new();
        m.push(2.0, 3.0, 4);
        m.push(1.0, 4.0, 4);
        assert!((m.mean_loss() - 1.5).abs() < 1e-12);
        assert!((m.top1() - 7.0 / 8.0).abs() < 1e-12);
        assert_eq!(m.count(), 2);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn device_usage_balanced_cluster() {
        let mut u = DeviceUsage::new(3);
        u.record(&[2.0, 2.0, 2.0]);
        u.record(&[3.0, 3.0, 3.0]);
        assert_eq!(u.steps(), 2);
        assert!((u.mean_utilization() - 1.0).abs() < 1e-12);
        assert!(u.imbalance().abs() < 1e-12);
    }

    #[test]
    fn device_usage_straggler() {
        let mut u = DeviceUsage::new(2);
        u.record(&[1.0, 3.0]); // device 1 is the straggler
        let util = u.utilization();
        assert!((util[0] - 1.0 / 3.0).abs() < 1e-12);
        assert!((util[1] - 1.0).abs() < 1e-12);
        // mean busy = 2, max = 3 -> imbalance 0.5
        assert!((u.imbalance() - 0.5).abs() < 1e-12);
        assert!((u.mean_utilization() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn device_usage_empty_is_zero() {
        let u = DeviceUsage::new(4);
        assert_eq!(u.utilization(), vec![0.0; 4]);
        assert_eq!(u.mean_utilization(), 0.0);
        assert_eq!(u.imbalance(), 0.0);
    }

    #[test]
    fn device_usage_single_device_is_always_balanced() {
        let mut u = DeviceUsage::new(1);
        u.record(&[5.0]);
        u.record(&[2.0]);
        // One device IS the straggler and the mean: imbalance must be
        // exactly 0, utilization exactly 1.
        assert_eq!(u.imbalance(), 0.0);
        assert!((u.mean_utilization() - 1.0).abs() < 1e-12);
        assert_eq!(u.n_devices(), 1);
    }

    #[test]
    fn device_usage_all_zero_busy_times() {
        let mut u = DeviceUsage::new(3);
        u.record(&[0.0, 0.0, 0.0]);
        u.record(&[0.0, 0.0, 0.0]);
        // Zero mean busy time must not divide by zero: a cluster that
        // did no work is reported balanced and idle, not NaN.
        assert_eq!(u.imbalance(), 0.0);
        assert_eq!(u.utilization(), vec![0.0; 3]);
        assert_eq!(u.mean_utilization(), 0.0);
        assert_eq!(u.total_makespan_ms(), 0.0);
        assert_eq!(u.steps(), 2);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.push(0.0);
        for _ in 0..20 {
            e.push(1.0);
        }
        assert!((e.get().unwrap() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Top-1"]);
        t.row(&["D2FT (Ours)".into(), "89.4%".into()]);
        t.row(&["Random".into(), "44.4%".into()]);
        let s = t.render();
        assert!(s.contains("| D2FT (Ours) | 89.4% |"));
        assert!(s.lines().count() == 4);
        // all lines same width
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.894), "89.4%");
        assert_eq!(pct(1.0), "100.0%");
    }

    #[test]
    fn bytes_format_scales() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(1536), "1.5KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
        assert!(fmt_bytes(5 * 1024 * 1024 * 1024).ends_with("GiB"));
    }

    #[test]
    fn bytes_format_unit_boundaries() {
        // 1023B is the last exact-byte rendering; 1024B flips to KiB.
        assert_eq!(fmt_bytes(1023), "1023B");
        assert_eq!(fmt_bytes(1024), "1.0KiB");
        // 1048535B = 1023.96KiB: one-decimal rounding would print the
        // nonsensical "1024.0KiB" — must promote to MiB instead.
        assert_eq!(fmt_bytes(1_048_535), "1.00MiB");
        // Just below the rounding threshold stays in KiB.
        assert_eq!(fmt_bytes(1_048_471), "1023.9KiB");
        assert_eq!(fmt_bytes(1024 * 1024), "1.00MiB");
        // Same at the MiB -> GiB boundary (two decimals round from
        // 1023.995): 1073736377B = 1023.99561MiB.
        assert_eq!(fmt_bytes(1_073_736_377), "1.00GiB");
        assert_eq!(fmt_bytes(1_073_731_338), "1023.99MiB");
        assert_eq!(fmt_bytes(1024 * 1024 * 1024), "1.00GiB");
    }

    #[test]
    fn rel_drift_basics() {
        assert!((rel_drift(12.0, 10.0) - 0.2).abs() < 1e-12);
        assert!((rel_drift(8.0, 10.0) - 0.2).abs() < 1e-12);
        assert_eq!(rel_drift(1.0, 0.0), 0.0, "empty measurement");
        assert_eq!(rel_drift(10.0, 10.0), 0.0);
    }
}
