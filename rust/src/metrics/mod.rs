//! Training/eval metrics and report formatting (markdown tables that
//! mirror the paper's tables; consumed by EXPERIMENTS.md).

/// Online loss/accuracy accumulator.
#[derive(Clone, Debug, Default)]
pub struct Meter {
    n: usize,
    loss_sum: f64,
    correct: f64,
    total: f64,
}

impl Meter {
    pub fn new() -> Meter {
        Meter::default()
    }

    pub fn push(&mut self, loss: f32, n_correct: f32, batch: usize) {
        self.n += 1;
        self.loss_sum += loss as f64;
        self.correct += n_correct as f64;
        self.total += batch as f64;
    }

    pub fn mean_loss(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.loss_sum / self.n as f64
        }
    }

    pub fn top1(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            self.correct / self.total
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn reset(&mut self) {
        *self = Meter::default();
    }
}

/// Simple exponential moving average for loss curves.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Markdown table writer with aligned columns.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a fraction as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates() {
        let mut m = Meter::new();
        m.push(2.0, 3.0, 4);
        m.push(1.0, 4.0, 4);
        assert!((m.mean_loss() - 1.5).abs() < 1e-12);
        assert!((m.top1() - 7.0 / 8.0).abs() < 1e-12);
        assert_eq!(m.count(), 2);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.push(0.0);
        for _ in 0..20 {
            e.push(1.0);
        }
        assert!((e.get().unwrap() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Top-1"]);
        t.row(&["D2FT (Ours)".into(), "89.4%".into()]);
        t.row(&["Random".into(), "44.4%".into()]);
        let s = t.render();
        assert!(s.contains("| D2FT (Ours) | 89.4% |"));
        assert!(s.lines().count() == 4);
        // all lines same width
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.894), "89.4%");
        assert_eq!(pct(1.0), "100.0%");
    }
}
