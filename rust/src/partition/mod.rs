//! Model partitioning (paper §II-A1, §IV-B, §IV-D).
//!
//! The transformer body is partitioned depth-wise (blocks) and width-wise
//! (attention heads + matching FFN chunks). The minimal subnet is one
//! head + 1/H of the block's FFN; coarser partitions group consecutive
//! heads (the paper's 38- and 26-subnet configs, and the "large memory
//! device" heterogeneity setting). Two extra subnets hold the patch
//! embedding and the pooling/classifier — they participate in every
//! operation (the schedule only orchestrates body subnets).

use crate::runtime::ModelConfig;

/// One schedulable subnet: a contiguous group of heads in one block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Subnet {
    /// Block (layer) index.
    pub block: usize,
    /// First head in the group.
    pub head_lo: usize,
    /// One past the last head in the group.
    pub head_hi: usize,
}

impl Subnet {
    /// Number of heads this subnet owns.
    pub fn n_heads(&self) -> usize {
        self.head_hi - self.head_lo
    }

    /// The head indices this subnet owns.
    pub fn heads(&self) -> impl Iterator<Item = usize> {
        self.head_lo..self.head_hi
    }
}

/// A full partitioning of the model body into schedulable subnets.
///
/// `n_devices() == subnets.len()` in the default 1:1 placement (paper
/// footnote 1); heterogeneity experiments remap via `cluster::hetero`.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Transformer depth (blocks).
    pub depth: usize,
    /// Attention heads per block.
    pub heads: usize,
    /// The schedulable subnets, in (block, head) order.
    pub subnets: Vec<Subnet>,
}

impl Partition {
    /// Finest partition: one subnet per (block, head) — the paper's
    /// 74-subnet setting on ViT-small (72 body + embed + classifier).
    pub fn per_head(cfg: &ModelConfig) -> Partition {
        Self::grouped(cfg, 1)
    }

    /// Group `group` consecutive heads per subnet (paper's 38-subnet
    /// config is group=2 on ViT-small, 26-subnet is group=3).
    pub fn grouped(cfg: &ModelConfig, group: usize) -> Partition {
        assert!(
            group >= 1 && cfg.heads % group == 0,
            "head count {} not divisible by group {}",
            cfg.heads,
            group
        );
        let mut subnets = Vec::new();
        for block in 0..cfg.depth {
            for g in 0..(cfg.heads / group) {
                subnets.push(Subnet {
                    block,
                    head_lo: g * group,
                    head_hi: (g + 1) * group,
                });
            }
        }
        Partition { depth: cfg.depth, heads: cfg.heads, subnets }
    }

    /// Mixed grouping for memory heterogeneity (paper §IV-D): the first
    /// `n_large` *pairs* of per-head subnets are merged into 2-head
    /// subnets ("large memory devices"), the rest stay per-head.
    pub fn heterogeneous(cfg: &ModelConfig, n_large: usize) -> Partition {
        let fine = Self::per_head(cfg);
        let mut subnets = Vec::new();
        let mut merged = 0;
        let mut i = 0;
        while i < fine.subnets.len() {
            let a = fine.subnets[i];
            let can_pair = merged < n_large
                && i + 1 < fine.subnets.len()
                && fine.subnets[i + 1].block == a.block
                && fine.subnets[i + 1].head_lo == a.head_hi;
            if can_pair {
                subnets.push(Subnet { block: a.block, head_lo: a.head_lo, head_hi: a.head_hi + 1 });
                merged += 1;
                i += 2;
            } else {
                subnets.push(a);
                i += 1;
            }
        }
        Partition { depth: cfg.depth, heads: cfg.heads, subnets }
    }

    /// Number of schedulable (body) subnets.
    pub fn n_subnets(&self) -> usize {
        self.subnets.len()
    }

    /// Total device count including the 2 non-schedulable subnets
    /// (patch embedding, classifier) — the paper's "74" accounting.
    pub fn n_devices_total(&self) -> usize {
        self.subnets.len() + 2
    }

    /// Check full disjoint cover of the (block, head) grid.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut covered = vec![false; self.depth * self.heads];
        for s in &self.subnets {
            anyhow::ensure!(s.block < self.depth, "block {} out of range", s.block);
            anyhow::ensure!(s.head_lo < s.head_hi && s.head_hi <= self.heads,
                            "bad head range {}..{}", s.head_lo, s.head_hi);
            for h in s.heads() {
                let idx = s.block * self.heads + h;
                anyhow::ensure!(!covered[idx], "head ({}, {h}) covered twice", s.block);
                covered[idx] = true;
            }
        }
        anyhow::ensure!(covered.iter().all(|&c| c), "partition does not cover all heads");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn cfg(depth: usize, heads: usize) -> ModelConfig {
        ModelConfig {
            img_size: 32, patch: 4, dim: heads * 32, depth, heads,
            mlp_ratio: 4, classes: 10, lora_rank: 0, head_dim: 32,
            tokens: 65,
        }
    }

    #[test]
    fn per_head_counts_match_paper() {
        // ViT-small: 12 blocks x 6 heads -> 72 body subnets + 2 = 74.
        let p = Partition::per_head(&cfg(12, 6));
        assert_eq!(p.n_subnets(), 72);
        assert_eq!(p.n_devices_total(), 74);
        p.validate().unwrap();
        // 38- and 26-subnet configs of Table V.
        assert_eq!(Partition::grouped(&cfg(12, 6), 2).n_devices_total(), 38);
        assert_eq!(Partition::grouped(&cfg(12, 6), 3).n_devices_total(), 26);
    }

    #[test]
    fn grouped_partitions_validate() {
        for g in [1, 2, 3, 6] {
            Partition::grouped(&cfg(12, 6), g).validate().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_bad_group() {
        Partition::grouped(&cfg(12, 6), 4);
    }

    #[test]
    fn heterogeneous_merges_exactly_n_large() {
        let c = cfg(12, 6);
        for n_large in [0, 9, 14, 19] {
            let p = Partition::heterogeneous(&c, n_large);
            p.validate().unwrap();
            let large = p.subnets.iter().filter(|s| s.n_heads() == 2).count();
            assert_eq!(large, n_large);
            assert_eq!(p.n_subnets(), 72 - n_large);
        }
    }

    #[test]
    fn property_partitions_cover_disjointly() {
        check("partition-cover", 40, |g| {
            let depth = g.usize_in(1, 8);
            let heads = *g.pick(&[2usize, 4, 6]);
            let c = cfg(depth, heads);
            let divisors: Vec<usize> = (1..=heads).filter(|d| heads % d == 0).collect();
            let group = *g.pick(&divisors);
            let p = Partition::grouped(&c, group);
            p.validate().map_err(|e| e.to_string())?;
            if p.n_subnets() != depth * heads / group {
                return Err("wrong subnet count".into());
            }
            Ok(())
        });
    }
}
