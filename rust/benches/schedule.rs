//! Bench: scheduling algorithms on the paper's 72-subnet x 5-micro-batch
//! instance (the L3 hot path that runs once per batch).
//!
//! Perf target (DESIGN.md §Perf): full-schedule construction < 1 ms so
//! scheduling never gates a training step.

use std::time::Duration;

use d2ft::cluster::CostModel;
use d2ft::partition::Partition;
use d2ft::runtime::ModelConfig;
use d2ft::schedule::bilevel::BiLevel;
use d2ft::schedule::dpruning::DPruning;
use d2ft::schedule::random_sched::RandomSched;
use d2ft::schedule::scaler::{Lambda, ScalerSched};
use d2ft::schedule::{Budget, Scheduler};
use d2ft::scores::{Metric, ScoreBook, ScoreConfig};
use d2ft::util::bench::{black_box, Bench};
use d2ft::util::rng::Rng;

fn vit_small() -> ModelConfig {
    ModelConfig {
        img_size: 224, patch: 16, dim: 384, depth: 12, heads: 6,
        mlp_ratio: 4, classes: 196, lora_rank: 0, head_dim: 64, tokens: 197,
    }
}

fn book(n_subnets: usize, n_micro: usize) -> ScoreBook {
    let mut rng = Rng::new(1);
    let mut b = ScoreBook::zeros(n_subnets, n_micro);
    for k in 0..n_subnets {
        for i in 0..n_micro {
            for m in [Metric::Fisher, Metric::GradMag, Metric::Taylor, Metric::WeightMag] {
                b.set(m, k, i, rng.next_f64() * 10.0);
            }
        }
    }
    b
}

fn main() {
    let part = Partition::per_head(&vit_small());
    let b5 = book(part.n_subnets(), 5);
    let b20 = book(part.n_subnets(), 20);
    let budget5 = Budget::uniform(5, 3, 1);
    let budget20 = Budget::uniform(20, 8, 8);
    let t = Duration::from_millis(800);

    let mut d2ft = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    Bench::new("d2ft-bilevel-72x5")
        .target_time(t)
        .run(|| black_box(d2ft.schedule(&b5, &budget5)))
        .report();
    Bench::new("d2ft-bilevel-72x20")
        .target_time(t)
        .run(|| black_box(d2ft.schedule(&b20, &budget20)))
        .report();

    let mut scaler = ScalerSched::new(Lambda::Max, ScoreConfig::default(), CostModel::paper());
    Bench::new("scaler-max-72x5")
        .target_time(t)
        .run(|| black_box(scaler.schedule(&b5, &budget5)))
        .report();

    let mut random = RandomSched::new(3);
    Bench::new("random-72x5")
        .target_time(t)
        .run(|| black_box(random.schedule(&b5, &budget5)))
        .report();

    let mut dp = DPruning::magnitude();
    Bench::new("dpruning-m-72x5")
        .target_time(t)
        .run(|| black_box(dp.schedule(&b5, &budget5)))
        .report();

    // Schedule-to-mask lowering (runs per micro-batch in the hot loop).
    let table = d2ft.schedule(&b5, &budget5);
    Bench::new("masks-for-micro-72")
        .target_time(t)
        .run(|| black_box(table.masks_for_micro(&part, 2)))
        .report();
}
