//! Bench: paper Table IV — real PJRT execution time of the fused p_f
//! trainstep vs the p_o forward pass for 1..5 micro-batches on this
//! host. Requires the `xla` feature + `make artifacts`; the
//! backend-agnostic runner is `repro experiment table4`.

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "table4 bench requires --features xla; run `repro experiment table4` for the native path"
    );
}

#[cfg(feature = "xla")]
fn main() {
    use d2ft::cluster::ExecTimeModel;
    use d2ft::data::{DatasetSpec, SyntheticKind};
    use d2ft::runtime::{ArtifactRegistry, ParamStore, Session, TrainState};
    use d2ft::schedule::{MaskPair, Op};

    let registry = match ArtifactRegistry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping table4 bench (no artifacts): {e}");
            return;
        }
    };
    let manifest = &registry.full_manifest;
    let session = Session::new(&registry, manifest).unwrap();
    let store = ParamStore::load(manifest, registry.dir()).unwrap();
    let mut state = TrainState::new(&store).unwrap();
    let mc = &manifest.config;
    let mb = manifest.micro_batch;
    let d = DatasetSpec::preset(SyntheticKind::Cifar100Like, mc.img_size, mb, 5).generate("train");
    let (xt, yt) = d.gather(&(0..mb).collect::<Vec<_>>());
    let x = session.x_literal(&xt).unwrap();
    let y = session.y_literal(&yt).unwrap();
    let masks = MaskPair::ones(mc.depth, mc.heads);
    // warmup
    session.step(&mut state, &x, &y, &masks, 0.0).unwrap();
    session.eval(&state, &x, &y, None).unwrap();

    let paper = ExecTimeModel::paper();
    println!(
        "{:>3} {:>14} {:>14} {:>12} {:>12} {:>10}",
        "n", "p_f host", "p_o host", "p_f paper", "p_o paper", "ratio"
    );
    for n in 1..=5usize {
        let reps = 3usize;
        let mut full_best = f64::INFINITY;
        let mut fwd_best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            for _ in 0..n {
                session.step(&mut state, &x, &y, &masks, 0.0).unwrap();
            }
            full_best = full_best.min(t0.elapsed().as_secs_f64() * 1e3);
            let t1 = std::time::Instant::now();
            for _ in 0..n {
                session.eval(&state, &x, &y, None).unwrap();
            }
            fwd_best = fwd_best.min(t1.elapsed().as_secs_f64() * 1e3);
        }
        println!(
            "{n:>3} {full_best:>12.2}ms {fwd_best:>12.2}ms {:>10.2}ms {:>10.2}ms {:>10.2}",
            paper.time_ms(Op::Full, n),
            paper.time_ms(Op::ForwardOnly, n),
            fwd_best / full_best,
        );
    }
    println!("(paper Table IV ratio ~= 0.40 — the cost model's c_f calibration)");
}
