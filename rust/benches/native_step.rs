//! Bench: the native backend's fused step (fwd + bwd + SGD-momentum)
//! across micro-batch sizes and LoRA ranks, plus the eval forward and
//! the score probe. Artifact-free; writes `BENCH_native_step.json`.
//!
//!     cargo bench --bench native_step

#[cfg(not(feature = "native"))]
fn main() {
    eprintln!("native_step bench requires the default `native` feature");
}

#[cfg(feature = "native")]
use d2ft::backend::native::{NativeBackend, NativeSpec};
#[cfg(feature = "native")]
use d2ft::backend::Backend;
#[cfg(feature = "native")]
use d2ft::data::{DatasetSpec, SyntheticKind};
#[cfg(feature = "native")]
use d2ft::schedule::MaskPair;
#[cfg(feature = "native")]
use d2ft::util::json::{arr, num, obj, s};

#[cfg(feature = "native")]
const REPS: usize = 7;
#[cfg(feature = "native")]
const STEPS_PER_REP: usize = 5;

/// Best-of-REPS mean ms per call of `f` over STEPS_PER_REP calls.
#[cfg(feature = "native")]
fn time_ms(mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = std::time::Instant::now();
        for _ in 0..STEPS_PER_REP {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() * 1e3 / STEPS_PER_REP as f64);
    }
    best
}

#[cfg(feature = "native")]
fn main() {
    let spec = NativeSpec::tiny();
    let mc = spec.config.clone();
    let masks = MaskPair::ones(mc.depth, mc.heads);
    println!(
        "native_step: ViT d{} x{}L x{}H, best of {REPS} x {STEPS_PER_REP} steps",
        mc.dim, mc.depth, mc.heads
    );

    let mut entries = Vec::new();
    // Micro-batch sweep at rank 0 (full fine-tuning), then the LoRA
    // ranks at the default micro-batch.
    let mut settings: Vec<(usize, usize)> = Vec::new();
    let mut mbs = spec.mb_variants.clone();
    mbs.push(spec.micro_batch);
    mbs.sort_unstable();
    for &mb in &mbs {
        settings.push((mb, 0));
    }
    for &rank in &spec.lora_ranks {
        settings.push((spec.micro_batch, rank));
    }

    for (mb, rank) in settings {
        let data = DatasetSpec::preset(SyntheticKind::Cifar100Like, mc.img_size, mb, 7)
            .generate("train");
        let (x, y) = data.gather(&(0..mb).collect::<Vec<_>>());
        let mut be = NativeBackend::new(&spec, rank, mb, 11);
        // warmup
        be.step(&x, &y, &masks, 0.01).unwrap();
        let step_ms = time_ms(|| {
            be.step(&x, &y, &masks, 0.01).unwrap();
        });
        let eval_ms = time_ms(|| {
            be.eval(&x, &y, None).unwrap();
        });
        let probe_ms = time_ms(|| {
            be.score_probe(&x, &y).unwrap();
        });
        println!(
            "bench native mb={mb:<2} rank={rank:<2} step {step_ms:>8.3}ms  \
             eval {eval_ms:>8.3}ms  probe {probe_ms:>8.3}ms  \
             (eval/step {:.2})",
            eval_ms / step_ms
        );
        entries.push(obj(vec![
            ("micro_batch", num(mb as f64)),
            ("lora_rank", num(rank as f64)),
            ("step_ms", num(step_ms)),
            ("eval_ms", num(eval_ms)),
            ("probe_ms", num(probe_ms)),
            ("eval_over_step", num(eval_ms / step_ms)),
        ]));
    }

    let report = obj(vec![
        ("bench", s("native_step")),
        ("reps", num(REPS as f64)),
        ("steps_per_rep", num(STEPS_PER_REP as f64)),
        (
            "model",
            obj(vec![
                ("dim", num(mc.dim as f64)),
                ("depth", num(mc.depth as f64)),
                ("heads", num(mc.heads as f64)),
                ("tokens", num(mc.tokens as f64)),
                ("classes", num(mc.classes as f64)),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_native_step.json";
    std::fs::write(path, report.to_string_pretty()).expect("writing bench report");
    println!("wrote {path}");
}
