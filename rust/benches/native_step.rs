//! Bench: the native backend's fused step (fwd + bwd + SGD-momentum)
//! across micro-batch sizes and LoRA ranks, plus the eval forward and
//! the score probe. Artifact-free; writes `BENCH_native_step.json`.
//!
//!     cargo bench --bench native_step

#[cfg(not(feature = "native"))]
fn main() {
    eprintln!("native_step bench requires the default `native` feature");
}

#[cfg(feature = "native")]
use d2ft::backend::native::{NativeBackend, NativeSpec};
#[cfg(feature = "native")]
use d2ft::backend::Backend;
#[cfg(feature = "native")]
use d2ft::data::{DatasetSpec, SyntheticKind};
#[cfg(feature = "native")]
use d2ft::schedule::MaskPair;
#[cfg(feature = "native")]
use d2ft::tensor::linalg::reference;
#[cfg(feature = "native")]
use d2ft::tensor::Tensor;
#[cfg(feature = "native")]
use d2ft::util::bench::black_box;
#[cfg(feature = "native")]
use d2ft::util::json::{arr, num, obj, s};
#[cfg(feature = "native")]
use d2ft::util::rng::Rng;

#[cfg(feature = "native")]
const REPS: usize = 7;
#[cfg(feature = "native")]
const STEPS_PER_REP: usize = 5;

/// Best-of-REPS mean ms per call of `f` over STEPS_PER_REP calls.
#[cfg(feature = "native")]
fn time_ms(mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = std::time::Instant::now();
        for _ in 0..STEPS_PER_REP {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() * 1e3 / STEPS_PER_REP as f64);
    }
    best
}

#[cfg(feature = "native")]
fn main() {
    let spec = NativeSpec::tiny();
    let mc = spec.config.clone();
    let masks = MaskPair::ones(mc.depth, mc.heads);
    println!(
        "native_step: ViT d{} x{}L x{}H, best of {REPS} x {STEPS_PER_REP} steps",
        mc.dim, mc.depth, mc.heads
    );

    let mut entries = Vec::new();
    // Micro-batch sweep at rank 0 (full fine-tuning), then the LoRA
    // ranks at the default micro-batch.
    let mut settings: Vec<(usize, usize)> = Vec::new();
    let mut mbs = spec.mb_variants.clone();
    mbs.push(spec.micro_batch);
    mbs.sort_unstable();
    for &mb in &mbs {
        settings.push((mb, 0));
    }
    for &rank in &spec.lora_ranks {
        settings.push((spec.micro_batch, rank));
    }

    for (mb, rank) in settings {
        let data = DatasetSpec::preset(SyntheticKind::Cifar100Like, mc.img_size, mb, 7)
            .generate("train");
        let (x, y) = data.gather(&(0..mb).collect::<Vec<_>>());
        let mut be = NativeBackend::new(&spec, rank, mb, 11);
        // warmup
        be.step(&x, &y, &masks, 0.01).unwrap();
        let step_ms = time_ms(|| {
            be.step(&x, &y, &masks, 0.01).unwrap();
        });
        let eval_ms = time_ms(|| {
            be.eval(&x, &y, None).unwrap();
        });
        let probe_ms = time_ms(|| {
            be.score_probe(&x, &y).unwrap();
        });
        println!(
            "bench native mb={mb:<2} rank={rank:<2} step {step_ms:>8.3}ms  \
             eval {eval_ms:>8.3}ms  probe {probe_ms:>8.3}ms  \
             (eval/step {:.2})",
            eval_ms / step_ms
        );
        entries.push(obj(vec![
            ("micro_batch", num(mb as f64)),
            ("lora_rank", num(rank as f64)),
            ("step_ms", num(step_ms)),
            ("eval_ms", num(eval_ms)),
            ("probe_ms", num(probe_ms)),
            ("eval_over_step", num(eval_ms / step_ms)),
        ]));
    }

    // --- tiled vs naive matmul kernels -------------------------------------
    // The tiled kernels are bitwise identical to `linalg::reference` (a
    // unit test pins that); here we assert they are also *faster* on a
    // backward-pass-shaped `dX = dY W^T`, where the naive kernel's
    // serial dot-product reduction leaves all the ILP on the table.
    let rand_t = |shape: &[usize], seed: u64| -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.next_normal()).collect())
    };
    let a = rand_t(&[192, 256], 31);
    let bt = rand_t(&[320, 256], 32);
    let tiled_ms = time_ms(|| {
        black_box(a.matmul_nt(&bt));
    });
    let naive_ms = time_ms(|| {
        black_box(reference::matmul_nt(&a, &bt));
    });
    let speedup = naive_ms / tiled_ms;
    println!(
        "bench matmul_nt 192x256x320: tiled {tiled_ms:.3}ms vs naive {naive_ms:.3}ms \
         (speedup {speedup:.2}x)"
    );
    // Hard floor: tiling must never make the hot path slower. The full
    // >10% speedup expectation is asserted only in strict mode so a
    // throttled shared CI runner cannot flake the job on timing noise
    // (the JSON report always records the measured ratio).
    assert!(
        speedup > 0.9,
        "tiled matmul_nt regressed vs the naive reference: {speedup:.2}x"
    );
    if std::env::var_os("D2FT_STRICT_BENCH").is_some() {
        assert!(
            speedup > 1.1,
            "tiled matmul_nt should beat the naive reference by >10%, got {speedup:.2}x"
        );
    } else if speedup <= 1.1 {
        eprintln!("WARNING: tiled speedup {speedup:.2}x below the 1.1x expectation");
    }

    // --- threaded vs single-thread matmul at the small-model shape ---
    // The ViT-small FFN up-projection over a mb=8 token stream:
    // [mb*T, D] x [D, 4D] = [136, 96] x [96, 384]. Writer-owned row
    // blocks keep the threaded result bitwise identical (unit-tested);
    // here we assert the parallelism is also a *measured* win.
    use d2ft::tensor::pool;
    let sm = NativeSpec::small().config;
    let ta = rand_t(&[8 * sm.tokens, sm.dim], 61);
    let tb = rand_t(&[sm.dim, sm.mlp_ratio * sm.dim], 62);
    pool::configure(1);
    let single_ms = time_ms(|| {
        black_box(ta.matmul(&tb));
    });
    pool::configure(0); // auto: one thread per core, capped at 8
    let kernel_threads = pool::threads();
    let multi_ms = time_ms(|| {
        black_box(ta.matmul(&tb));
    });
    pool::configure(1);
    let thread_speedup = single_ms / multi_ms;
    println!(
        "bench matmul 136x96x384 (small-model FFN): 1 thread {single_ms:.3}ms vs \
         {kernel_threads} threads {multi_ms:.3}ms (speedup {thread_speedup:.2}x)"
    );
    if kernel_threads >= 2 {
        assert!(
            thread_speedup > 1.05,
            "threaded matmul must beat single-thread at the small-model shape, \
             got {thread_speedup:.2}x on {kernel_threads} threads"
        );
        if std::env::var_os("D2FT_STRICT_BENCH").is_some() {
            assert!(
                thread_speedup > 1.3,
                "threaded matmul should beat single-thread by >30%, got {thread_speedup:.2}x"
            );
        }
    } else {
        eprintln!("WARNING: single-core host; skipping the threaded-matmul assertion");
    }

    let report = obj(vec![
        ("bench", s("native_step")),
        (
            "matmul_nt_192x256x320",
            obj(vec![
                ("tiled_ms", num(tiled_ms)),
                ("naive_ms", num(naive_ms)),
                ("speedup", num(speedup)),
            ]),
        ),
        (
            "threaded_matmul_136x96x384",
            obj(vec![
                ("single_ms", num(single_ms)),
                ("multi_ms", num(multi_ms)),
                ("threads", num(kernel_threads as f64)),
                ("speedup", num(thread_speedup)),
            ]),
        ),
        ("reps", num(REPS as f64)),
        ("steps_per_rep", num(STEPS_PER_REP as f64)),
        (
            "model",
            obj(vec![
                ("dim", num(mc.dim as f64)),
                ("depth", num(mc.depth as f64)),
                ("heads", num(mc.heads as f64)),
                ("tokens", num(mc.tokens as f64)),
                ("classes", num(mc.classes as f64)),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_native_step.json";
    std::fs::write(path, report.to_string_pretty()).expect("writing bench report");
    println!("wrote {path}");
}
