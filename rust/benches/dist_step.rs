//! Bench: the distributed data-parallel trainer — per-step wall time
//! across worker counts and **measured** gradient bytes on the wire for
//! the paper's 50%-communication D2FT budget vs the full (unmasked)
//! schedule. Artifact-free; writes `BENCH_dist_step.json`.
//!
//!     cargo bench --bench dist_step
//!
//! Asserts the headline claim: the masked wire format ships >= 40%
//! fewer gradient bytes than full fine-tuning under the 50% budget.

#[cfg(not(feature = "native"))]
fn main() {
    eprintln!("dist_step bench requires the default `native` feature");
}

#[cfg(feature = "native")]
fn main() {
    use d2ft::backend::native::NativeProvider;
    use d2ft::coordinator::{SchedulerKind, TrainerConfig, UpdateMode};
    use d2ft::data::SyntheticKind;
    use d2ft::dist::{DistConfig, DistReport, DistTrainer, ExchangeMode};
    use d2ft::metrics::{fmt_bytes, pct};
    use d2ft::schedule::Budget;
    use d2ft::util::json::{arr, num, obj, s};

    const BATCHES: usize = 6;

    let provider = NativeProvider::default();
    // No pretrain: `DistReport::wire` already excludes pretrain
    // traffic, so this only keeps the runs short.
    let base = |scheduler, budget| TrainerConfig {
        train_size: 240,
        test_size: 24,
        batches: BATCHES,
        pretrain_batches: 0,
        update: UpdateMode::BatchAccum,
        ..TrainerConfig::quick(SyntheticKind::Cifar100Like, scheduler, budget)
    };
    let run = |scheduler, budget, workers: usize, exchange| -> DistReport {
        let dcfg = DistConfig { train: base(scheduler, budget), workers, exchange };
        DistTrainer::new(&provider, dcfg)
            .expect("building dist trainer")
            .run()
            .expect("dist run")
    };

    // The paper's 50%-communication budget (2 p_f + 1 p_o of 5) vs the
    // full unmasked schedule, both measured at K=4.
    let d2ft = run(
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 1),
        4,
        ExchangeMode::MaskedAllReduce,
    );
    let full = run(
        SchedulerKind::Standard,
        Budget::uniform(5, 5, 0),
        4,
        ExchangeMode::MaskedAllReduce,
    );
    let savings = 1.0 - d2ft.wire.up_bytes as f64 / full.wire.up_bytes as f64;
    println!(
        "grad bytes on the wire ({BATCHES} batches): d2ft {} vs full {} -> {} saved",
        fmt_bytes(d2ft.wire.up_bytes),
        fmt_bytes(full.wire.up_bytes),
        pct(savings)
    );
    assert!(
        savings >= 0.40,
        "50%-budget D2FT must ship >= 40% fewer gradient bytes, got {}",
        pct(savings)
    );
    assert!(
        (d2ft.grad_savings - savings).abs() < 1e-9,
        "dense-baseline accounting must agree with the standard-schedule run"
    );

    // Parameter-server downlink contrast (dense deltas).
    let ps = run(
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 1),
        4,
        ExchangeMode::ParamServer,
    );
    println!(
        "downlink: allreduce {} vs param-server {}",
        fmt_bytes(d2ft.wire.down_bytes),
        fmt_bytes(ps.wire.down_bytes)
    );

    // Wall time per step across worker counts.
    let mut sweep = Vec::new();
    for k in [1usize, 2, 4] {
        let r = run(
            SchedulerKind::D2ft,
            Budget::uniform(5, 2, 1),
            k,
            ExchangeMode::MaskedAllReduce,
        );
        println!(
            "K={k}: step {:.3}ms, straggler {:.3}ms, worker util {}",
            r.mean_step_ms,
            r.train.straggler_ms,
            pct(r.worker_utilization)
        );
        sweep.push(obj(vec![
            ("workers", num(k as f64)),
            ("mean_step_ms", num(r.mean_step_ms)),
            ("straggler_ms", num(r.train.straggler_ms)),
            ("worker_utilization", num(r.worker_utilization)),
            ("final_train_loss", num(r.train.final_train_loss)),
        ]));
    }

    let wire = |r: &DistReport| {
        obj(vec![
            ("up_bytes", num(r.wire.up_bytes as f64)),
            ("dense_up_bytes", num(r.wire.dense_up_bytes as f64)),
            ("down_bytes", num(r.wire.down_bytes as f64)),
            ("modeled_wire_bytes", num(r.modeled_wire_bytes as f64)),
            ("grad_savings", num(r.grad_savings)),
            ("mean_step_ms", num(r.mean_step_ms)),
            ("exchange", s(&r.exchange)),
        ])
    };
    let report = obj(vec![
        ("bench", s("dist_step")),
        ("batches", num(BATCHES as f64)),
        ("micros_per_batch", num(5.0)),
        ("budget", s("2 p_f + 1 p_o of 5 (50% comm)")),
        ("d2ft_50pct", wire(&d2ft)),
        ("full_schedule", wire(&full)),
        ("param_server", wire(&ps)),
        ("grad_bytes_saved_vs_full", num(savings)),
        ("worker_sweep", arr(sweep)),
    ]);
    let path = "BENCH_dist_step.json";
    std::fs::write(path, report.to_string_pretty()).expect("writing bench report");
    println!("wrote {path}");
}
