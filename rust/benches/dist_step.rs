//! Bench: the distributed data-parallel trainer — **measured** gradient
//! bytes on the wire for the paper's 50%-communication budget,
//! pipelined-vs-serialized makespan (comm/compute overlap), the kernel
//! thread sweep, the measured-time calibration loop, and the real
//! socket bytes of the same run over the TCP transport (reported next
//! to the modeled bytes, with a bitwise cross-transport check).
//! Artifact-free; writes `BENCH_dist_step.json` (compared against the
//! committed baseline `benches/BENCH_dist_step.baseline.json` by CI's
//! bench-regression gate).
//!
//!     cargo bench --bench dist_step
//!
//! Asserts the headline claims:
//! * the masked wire format ships >= 40% fewer gradient bytes than full
//!   fine-tuning under the 50% budget;
//! * the ring exchange keeps the aggregator's gradient-exchange socket
//!   bytes flat (within 25%) from K=2 to K=8 while the star's grow
//!   >= 2x, and its uncompressed trajectory is bitwise equal to the
//!   star (hence serial) one for K in {2, 4} on channel and TCP;
//! * int8 quantization shrinks the measured gradient uplink >= 3.5x
//!   and top-k (10%) >= 5x vs the f32 wire, with error feedback
//!   keeping the loss trajectory close;
//! * with a simulated NIC calibrated to ~1.5x one task's compute, the
//!   pipelined step (encode+upload overlapping the next task's
//!   `grad_step`) finishes the K=4 batch >= 1.2x faster than the
//!   serialized reference path;
//! * after one calibration epoch the modeled-vs-measured makespan drift
//!   reported in `TrainReport` is <= 20%.

#[cfg(not(feature = "native"))]
fn main() {
    eprintln!("dist_step bench requires the default `native` feature");
}

#[cfg(feature = "native")]
fn main() {
    use d2ft::backend::native::{NativeBackend, NativeProvider, NativeSpec};
    use d2ft::backend::Backend;
    use d2ft::coordinator::{SchedulerKind, TrainerConfig, UpdateMode};
    use d2ft::data::{DatasetSpec, SyntheticKind};
    use d2ft::dist::{
        DistConfig, DistReport, DistTrainer, ExchangeMode, GradCodec, SpawnMode, TransportKind,
        WireCompression,
    };
    use d2ft::metrics::{fmt_bytes, pct};
    use d2ft::schedule::{Budget, MaskPair};
    use d2ft::util::json::{arr, num, obj, s};

    const BATCHES: usize = 6;

    let provider = NativeProvider::default();
    // No pretrain: `DistReport::wire` already excludes pretrain
    // traffic, so this only keeps the runs short.
    let base = |scheduler, budget| {
        let mut c = TrainerConfig::quick(SyntheticKind::Cifar100Like, scheduler, budget);
        c.train_size = 240;
        c.test_size = 24;
        c.batches = BATCHES;
        c.pretrain_batches = 0;
        c.update = UpdateMode::BatchAccum;
        c
    };
    let run = |scheduler, budget, workers: usize, exchange| -> DistReport {
        let dcfg = DistConfig::builder(base(scheduler, budget), workers)
            .exchange(exchange)
            .build()
            .expect("dist config");
        DistTrainer::new(&provider, dcfg)
            .expect("building dist trainer")
            .run()
            .expect("dist run")
    };

    // The paper's 50%-communication budget (2 p_f + 1 p_o of 5) vs the
    // full unmasked schedule, both measured at K=4.
    let d2ft = run(
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 1),
        4,
        ExchangeMode::MaskedAllReduce,
    );
    let full = run(
        SchedulerKind::Standard,
        Budget::uniform(5, 5, 0),
        4,
        ExchangeMode::MaskedAllReduce,
    );
    let savings = 1.0 - d2ft.wire.up_bytes as f64 / full.wire.up_bytes as f64;
    println!(
        "grad bytes on the wire ({BATCHES} batches): d2ft {} vs full {} -> {} saved",
        fmt_bytes(d2ft.wire.up_bytes),
        fmt_bytes(full.wire.up_bytes),
        pct(savings)
    );
    assert!(
        savings >= 0.40,
        "50%-budget D2FT must ship >= 40% fewer gradient bytes, got {}",
        pct(savings)
    );
    assert!(
        (d2ft.grad_savings - savings).abs() < 1e-9,
        "dense-baseline accounting must agree with the standard-schedule run"
    );

    // Parameter-server downlink contrast (dense deltas).
    let ps = run(
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 1),
        4,
        ExchangeMode::ParamServer,
    );
    println!(
        "downlink: allreduce {} vs param-server {}",
        fmt_bytes(d2ft.wire.down_bytes),
        fmt_bytes(ps.wire.down_bytes)
    );

    // --- tcp transport: real socket bytes next to modeled bytes ------------
    // The same 50%-budget run over loopback TCP (worker threads, real
    // sockets): bitwise identical numerics, and the transport counters
    // report the bytes that actually crossed the socket — gradient
    // payloads plus framing, job dispatch, and broadcasts — next to the
    // engine's modeled figure.
    let tcp = {
        let dcfg = DistConfig::builder(base(SchedulerKind::D2ft, Budget::uniform(5, 2, 1)), 4)
            .transport(TransportKind::Tcp {
                listen: "127.0.0.1:0".to_string(),
                spawn: SpawnMode::Threads,
            })
            .build()
            .expect("tcp config");
        DistTrainer::new(&provider, dcfg)
            .expect("building tcp trainer")
            .run()
            .expect("tcp run")
    };
    let curve_bits = |r: &DistReport| -> Vec<u32> {
        r.train.loss_curve.iter().map(|x| x.to_bits()).collect()
    };
    assert_eq!(
        curve_bits(&d2ft),
        curve_bits(&tcp),
        "tcp transport must be bitwise identical to the channel transport"
    );
    assert_eq!(tcp.wire.up_bytes, d2ft.wire.up_bytes, "same gradient bytes on either pipe");
    assert!(
        tcp.socket.bytes_recv >= tcp.wire.up_bytes,
        "socket traffic must cover every gradient byte"
    );
    println!(
        "tcp socket bytes: {} in / {} out ({} frames) vs {} gradient uplink, \
         {} modeled",
        fmt_bytes(tcp.socket.bytes_recv),
        fmt_bytes(tcp.socket.bytes_sent),
        tcp.socket.frames_sent + tcp.socket.frames_recv,
        fmt_bytes(tcp.wire.up_bytes),
        fmt_bytes(tcp.modeled_wire_bytes)
    );

    // --- ring / hierarchical collectives -----------------------------------
    // The star aggregator's gradient-exchange traffic scales with K:
    // its downlink rebroadcasts one union blob per worker. The ring
    // aggregator's stays flat — one chain Final uplink per batch
    // regardless of K, with the partials riding worker<->worker links
    // the aggregator never sees. `grad_socket` sums the frame classes
    // that carry gradient payload on the aggregator's own links; job
    // dispatch is K-independent on both topologies and excluded, so
    // the contrast is purely the exchange.
    let run_ring = |exchange, workers: usize, tcp: bool| -> DistReport {
        let transport = if tcp {
            TransportKind::Tcp { listen: "127.0.0.1:0".to_string(), spawn: SpawnMode::Threads }
        } else {
            TransportKind::Channel
        };
        let dcfg =
            DistConfig::builder(base(SchedulerKind::D2ft, Budget::uniform(5, 2, 1)), workers)
                .exchange(exchange)
                .transport(transport)
                .build()
                .expect("ring config");
        DistTrainer::new(&provider, dcfg)
            .expect("building ring trainer")
            .run()
            .expect("ring run")
    };
    let grad_socket = |r: &DistReport| -> u64 {
        ["up", "apply", "deltas", "ring"]
            .into_iter()
            .map(|c| {
                let (tx, rx) = r.socket.class_bytes(c);
                tx + rx
            })
            .sum()
    };
    let ring2 = run_ring(ExchangeMode::Ring, 2, false);
    let ring4 = run_ring(ExchangeMode::Ring, 4, false);
    let ring8 = run_ring(ExchangeMode::Ring, 8, false);
    let star2 = run(
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 1),
        2,
        ExchangeMode::MaskedAllReduce,
    );
    let star8 = run(
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 1),
        8,
        ExchangeMode::MaskedAllReduce,
    );
    let ring_flat = grad_socket(&ring8) as f64 / grad_socket(&ring2) as f64;
    let star_growth = grad_socket(&star8) as f64 / grad_socket(&star2) as f64;
    println!(
        "exchange scaling K=2 -> K=8: ring {} -> {} ({ring_flat:.2}x), star {} -> {} \
         ({star_growth:.2}x)",
        fmt_bytes(grad_socket(&ring2)),
        fmt_bytes(grad_socket(&ring8)),
        fmt_bytes(grad_socket(&star2)),
        fmt_bytes(grad_socket(&star8))
    );
    assert!(
        (0.75..=1.25).contains(&ring_flat),
        "ring aggregator gradient traffic must stay within 25% from K=2 to K=8, \
         got {ring_flat:.2}x"
    );
    assert!(
        star_growth >= 2.0,
        "star aggregator gradient traffic must grow >= 2x from K=2 to K=8, \
         got {star_growth:.2}x"
    );
    assert!(
        ring8.ring_bytes.iter().map(|&(tx, rx)| tx + rx).sum::<u64>() > 0,
        "ring partials must ride worker<->worker links"
    );

    // Bitwise: the uncompressed chain fold adds the same values in the
    // same ascending micro-batch order as the ordered star reduce
    // (itself pinned bitwise-equal to the serial trainer in
    // tests/dist.rs), on either transport and through group leaders.
    let ring2t = run_ring(ExchangeMode::Ring, 2, true);
    let ring4t = run_ring(ExchangeMode::Ring, 4, true);
    let hier4 = run_ring(ExchangeMode::Hierarchical, 4, false);
    let star_bits = curve_bits(&d2ft);
    for (name, r) in [
        ("ring K=2 channel", &ring2),
        ("ring K=4 channel", &ring4),
        ("ring K=8 channel", &ring8),
        ("ring K=2 tcp", &ring2t),
        ("ring K=4 tcp", &ring4t),
        ("hierarchical K=4 channel", &hier4),
    ] {
        assert_eq!(
            star_bits,
            curve_bits(r),
            "{name} must keep the star (hence serial) loss trajectory bitwise"
        );
    }

    // --- compressed gradient wire -------------------------------------------
    // The same 50%-budget star run with the uplink quantized (int8:
    // per-slice scales, error-feedback residuals) or sparsified
    // (top-10% by magnitude, delta-coded indices). Masks, schedule, and
    // reduction order are unchanged, so `up_bytes` is directly
    // comparable against the f32 run above.
    let run_compress = |compress| -> DistReport {
        let dcfg = DistConfig::builder(base(SchedulerKind::D2ft, Budget::uniform(5, 2, 1)), 4)
            .compress(compress)
            .build()
            .expect("compressed config");
        DistTrainer::new(&provider, dcfg)
            .expect("building compressed trainer")
            .run()
            .expect("compressed run")
    };
    let q8 = run_compress(WireCompression::Int8);
    let topk = run_compress(WireCompression::TopK { pct: 10 });
    let int8_ratio = d2ft.wire.up_bytes as f64 / q8.wire.up_bytes as f64;
    let topk_ratio = d2ft.wire.up_bytes as f64 / topk.wire.up_bytes as f64;
    println!(
        "compressed uplink ({BATCHES} batches): f32 {} vs int8 {} ({int8_ratio:.2}x) vs \
         top-10% {} ({topk_ratio:.2}x)",
        fmt_bytes(d2ft.wire.up_bytes),
        fmt_bytes(q8.wire.up_bytes),
        fmt_bytes(topk.wire.up_bytes)
    );
    assert!(
        int8_ratio >= 3.5,
        "int8 must shrink the gradient uplink >= 3.5x vs f32, got {int8_ratio:.2}x"
    );
    assert!(
        topk_ratio >= 5.0,
        "top-10% must shrink the gradient uplink >= 5x vs f32, got {topk_ratio:.2}x"
    );

    // The wire layers compose: ring exchange with int8 partials (the
    // README quickstart / CI configuration) shrinks the chain traffic
    // too, and error feedback keeps every lossy trajectory training.
    let ring_q8 = {
        let dcfg = DistConfig::builder(base(SchedulerKind::D2ft, Budget::uniform(5, 2, 1)), 4)
            .exchange(ExchangeMode::Ring)
            .compress(WireCompression::Int8)
            .build()
            .expect("ring+int8 config");
        DistTrainer::new(&provider, dcfg)
            .expect("building ring+int8 trainer")
            .run()
            .expect("ring+int8 run")
    };
    let ring_chain = |r: &DistReport| -> u64 {
        let (tx, rx) = r.socket.class_bytes("ring");
        tx + rx + r.ring_bytes.iter().map(|&(s, v)| s + v).sum::<u64>()
    };
    let ring_q8_ratio = ring_chain(&ring4) as f64 / ring_chain(&ring_q8) as f64;
    println!(
        "ring chain traffic: f32 {} vs int8 {} ({ring_q8_ratio:.2}x)",
        fmt_bytes(ring_chain(&ring4)),
        fmt_bytes(ring_chain(&ring_q8))
    );
    assert!(
        ring_q8_ratio >= 3.0,
        "int8 must also shrink the ring chain traffic, got {ring_q8_ratio:.2}x"
    );
    for (name, r) in [("int8", &q8), ("top-10%", &topk), ("ring+int8", &ring_q8)] {
        let first = f64::from(*r.train.loss_curve.first().expect("loss curve"));
        let mean = r.train.final_train_loss;
        assert!(
            mean.is_finite() && mean < first,
            "{name} must still train under error feedback: first {first} mean {mean}"
        );
    }

    // --- comm/compute overlap: pipelined vs serialized ---------------------
    // In-process channels are effectively free, so the NIC is simulated
    // as a sleep per MiB of *actual encoded message* (DMA-like: no CPU
    // burnt), calibrated so one dense uplink costs ~1.5x one task's
    // measured grad_step — the comm ~ compute regime the engine's
    // pipeline model targets, and the ratio that keeps the measured
    // speedup stable across 2..8-core hosts.
    let spec = NativeSpec::tiny();
    let mb = spec.micro_batch;
    let probe = NativeBackend::new(&spec, 0, mb, 7);
    let cal_data =
        DatasetSpec::preset(SyntheticKind::Cifar100Like, spec.config.img_size, mb, 7)
            .generate("train");
    let (px, py) = cal_data.gather(&(0..mb).collect::<Vec<_>>());
    let ones = MaskPair::ones(spec.config.depth, spec.config.heads);
    probe.grad_step(&px, &py, &ones).expect("calibration warmup");
    const CAL_REPS: usize = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..CAL_REPS {
        probe.grad_step(&px, &py, &ones).expect("calibration step");
    }
    let task_ms = t0.elapsed().as_secs_f64() * 1e3 / CAL_REPS as f64;
    let dense_mib = GradCodec::new(&probe).dense_len() as f64 / (1024.0 * 1024.0);
    let wire_ms_per_mib = 1.5 * task_ms / dense_mib;
    println!(
        "overlap calibration: task {task_ms:.3}ms, dense msg {dense_mib:.3}MiB, \
         simulated NIC {wire_ms_per_mib:.1}ms/MiB"
    );

    // 12 micro-batches over K=4 workers = 3-deep pipelines per worker;
    // the Standard schedule keeps every message dense (max wire).
    let overlap_cfg = || {
        let mut c = TrainerConfig::quick(
            SyntheticKind::Cifar100Like,
            SchedulerKind::Standard,
            Budget::uniform(12, 12, 0),
        );
        c.train_size = 240;
        c.test_size = 24;
        c.batches = 4;
        c.pretrain_batches = 0;
        c.micros_per_batch = 12;
        c.update = UpdateMode::BatchAccum;
        c
    };
    let run_overlap = |overlap: bool, workers: usize| -> f64 {
        // Best of 2 runs: makespans are wall-clock, so take the less
        // disturbed sample of each mode.
        (0..2)
            .map(|_| {
                let dcfg = DistConfig::builder(overlap_cfg(), workers)
                    .overlap(overlap)
                    .sim_wire_ms_per_mib(wire_ms_per_mib)
                    .build()
                    .expect("overlap config");
                DistTrainer::new(&provider, dcfg)
                    .expect("building overlap trainer")
                    .run()
                    .expect("overlap run")
                    .mean_step_ms
            })
            .fold(f64::INFINITY, f64::min)
    };
    let pipelined_ms = run_overlap(true, 4);
    let serialized_ms = run_overlap(false, 4);
    let overlap_speedup = serialized_ms / pipelined_ms;
    println!(
        "K=4 x 12 micros: pipelined {pipelined_ms:.3}ms/step vs serialized \
         {serialized_ms:.3}ms/step (speedup {overlap_speedup:.2}x)"
    );
    assert!(
        overlap_speedup >= 1.2,
        "pipelined makespan must be >= 1.2x faster than the serialized path at K=4, \
         got {overlap_speedup:.2}x"
    );

    // Overlap x kernel-threads sweep (recorded, not asserted: with K
    // workers already saturating the cores, intra-op threading is a
    // wash on small hosts — the JSON shows whichever way it lands).
    let mut sweep = Vec::new();
    for threads in [1usize, 2] {
        let tspec = NativeSpec::builder().threads(threads).build().expect("sweep spec");
        let tp = NativeProvider::new(tspec);
        for overlap in [true, false] {
            let mut short_cfg = overlap_cfg();
            short_cfg.batches = 2;
            let dcfg = DistConfig::builder(short_cfg, 4)
                .overlap(overlap)
                .sim_wire_ms_per_mib(wire_ms_per_mib)
                .build()
                .expect("sweep config");
            let r = DistTrainer::new(&tp, dcfg)
                .expect("building sweep trainer")
                .run()
                .expect("sweep run");
            println!(
                "sweep threads={threads} overlap={overlap}: step {:.3}ms",
                r.mean_step_ms
            );
            sweep.push(obj(vec![
                ("threads", num(threads as f64)),
                ("overlap", s(if overlap { "on" } else { "off" })),
                ("mean_step_ms", num(r.mean_step_ms)),
            ]));
        }
    }

    // --- measured-time calibration: modeled-vs-measured drift --------------
    // 5 batches per epoch, 2 epochs: epoch 1 feeds the measured/modeled
    // ratio into ExecTimeModel::calibrated, epoch 2 reports the
    // residual drift. One retry because both sides are wall-clock on a
    // shared host (the retained run is printed either way).
    let calib_run = || -> DistReport {
        let mut cfg = TrainerConfig::quick(
            SyntheticKind::Cifar100Like,
            SchedulerKind::D2ft,
            Budget::uniform(5, 2, 1),
        );
        cfg.train_size = 100; // 5 batches/epoch at mb 4 x 5 micros
        cfg.test_size = 24;
        cfg.batches = 10;
        cfg.pretrain_batches = 1; // warmup: epoch 1 starts hot
        cfg.update = UpdateMode::BatchAccum;
        DistTrainer::new(&provider, DistConfig::new(cfg, 4))
            .expect("building calibration trainer")
            .run()
            .expect("calibration run")
    };
    let mut calib = calib_run();
    if calib.train.makespan_drift > 0.20 {
        eprintln!(
            "calibration drift {} on first attempt; retrying once",
            pct(calib.train.makespan_drift)
        );
        let retry = calib_run();
        if retry.train.makespan_drift < calib.train.makespan_drift {
            calib = retry;
        }
    }
    println!(
        "calibration: scale x{:.3} over {} epochs, model-vs-measured drift {}",
        calib.train.calib_scale,
        calib.train.calib_epochs,
        pct(calib.train.makespan_drift)
    );
    assert!(
        calib.train.calib_epochs >= 1,
        "two epochs must produce at least one calibration"
    );
    assert!(
        calib.train.makespan_drift <= 0.20,
        "after one calibration epoch the modeled makespan must track the measured \
         one within 20%, got {}",
        pct(calib.train.makespan_drift)
    );
    assert!(
        calib.encode_buf_reused > calib.encode_buf_fresh,
        "steady-state encode buffers must recycle: fresh {} vs reused {}",
        calib.encode_buf_fresh,
        calib.encode_buf_reused
    );

    // --- tracing overhead: armed recorder vs disarmed ----------------------
    // The step tracer must be cheap enough to leave on: every span is
    // two `Instant` reads and one ring-buffer slot, and a disarmed site
    // is a single relaxed atomic load. Best-of-3 mean step time, traced
    // vs untraced, on the same 50%-budget K=4 run; the gate is <= 5%.
    let trace_path =
        std::env::temp_dir().join(format!("d2ft_bench_trace_{}.json", std::process::id()));
    let run_traced = |trace: bool, trace_path: &std::path::Path| -> f64 {
        (0..3)
            .map(|_| {
                let dcfg =
                    DistConfig::builder(base(SchedulerKind::D2ft, Budget::uniform(5, 2, 1)), 4)
                        .trace_out(trace.then(|| trace_path.to_path_buf()))
                        .build()
                        .expect("tracing-bench config");
                DistTrainer::new(&provider, dcfg)
                    .expect("building tracing-bench trainer")
                    .run()
                    .expect("tracing-bench run")
                    .mean_step_ms
            })
            .fold(f64::INFINITY, f64::min)
    };
    let untraced_ms = run_traced(false, &trace_path);
    let traced_ms = run_traced(true, &trace_path);
    let trace_overhead = traced_ms / untraced_ms;
    println!(
        "tracing overhead: untraced {untraced_ms:.3}ms/step vs traced {traced_ms:.3}ms/step \
         ({:.1}%)",
        (trace_overhead - 1.0) * 100.0
    );
    let trace_text = std::fs::read_to_string(&trace_path).expect("reading bench trace artifact");
    assert!(
        trace_text.contains("traceEvents"),
        "the traced bench run must write a Chrome trace artifact"
    );
    std::fs::remove_file(&trace_path).ok();
    assert!(
        trace_overhead <= 1.05,
        "armed tracing must cost <= 5% of step time, got {:.1}% \
         (untraced {untraced_ms:.3}ms, traced {traced_ms:.3}ms)",
        (trace_overhead - 1.0) * 100.0
    );

    let wire = |r: &DistReport| {
        obj(vec![
            ("up_bytes", num(r.wire.up_bytes as f64)),
            ("dense_up_bytes", num(r.wire.dense_up_bytes as f64)),
            ("down_bytes", num(r.wire.down_bytes as f64)),
            ("modeled_wire_bytes", num(r.modeled_wire_bytes as f64)),
            ("grad_savings", num(r.grad_savings)),
            ("mean_step_ms", num(r.mean_step_ms)),
            ("exchange", s(&r.exchange)),
        ])
    };
    let report = obj(vec![
        ("bench", s("dist_step")),
        ("batches", num(BATCHES as f64)),
        ("micros_per_batch", num(5.0)),
        ("budget", s("2 p_f + 1 p_o of 5 (50% comm)")),
        ("d2ft_50pct", wire(&d2ft)),
        ("full_schedule", wire(&full)),
        ("param_server", wire(&ps)),
        (
            // Real socket traffic of the 50%-budget run over TCP,
            // reported next to the modeled figure (deterministic given
            // the seeds, unlike the timing metrics).
            "tcp_socket",
            obj(vec![
                ("bytes_recv", num(tcp.socket.bytes_recv as f64)),
                ("bytes_sent", num(tcp.socket.bytes_sent as f64)),
                ("frames", num((tcp.socket.frames_sent + tcp.socket.frames_recv) as f64)),
                ("grad_up_bytes", num(tcp.wire.up_bytes as f64)),
                ("modeled_wire_bytes", num(tcp.modeled_wire_bytes as f64)),
            ]),
        ),
        (
            // Criterion (a): flat ring vs K-scaling star, aggregator
            // gradient-exchange socket bytes (deterministic).
            "ring",
            obj(vec![
                ("grad_socket_k2", num(grad_socket(&ring2) as f64)),
                ("grad_socket_k8", num(grad_socket(&ring8) as f64)),
                ("flatness_k2_to_k8", num(ring_flat)),
                ("star_grad_socket_k2", num(grad_socket(&star2) as f64)),
                ("star_grad_socket_k8", num(grad_socket(&star8) as f64)),
                ("star_growth_k2_to_k8", num(star_growth)),
            ]),
        ),
        (
            // Criterion (b): measured byte reduction of the lossy wire
            // modes vs the f32 run, same masks and schedule.
            "compression",
            obj(vec![
                ("f32_up_bytes", num(d2ft.wire.up_bytes as f64)),
                ("int8_up_bytes", num(q8.wire.up_bytes as f64)),
                ("int8_ratio", num(int8_ratio)),
                ("topk10_up_bytes", num(topk.wire.up_bytes as f64)),
                ("topk10_ratio", num(topk_ratio)),
                ("ring_int8_chain_ratio", num(ring_q8_ratio)),
            ]),
        ),
        ("grad_bytes_saved_vs_full", num(savings)),
        // Host normalization anchor for the CI regression gate:
        // per-task times divide out absolute host speed.
        ("calib_task_ms", num(task_ms)),
        (
            "overlap",
            obj(vec![
                ("workers", num(4.0)),
                ("micros_per_batch", num(12.0)),
                ("wire_ms_per_mib", num(wire_ms_per_mib)),
                ("pipelined_mean_step_ms", num(pipelined_ms)),
                ("serialized_mean_step_ms", num(serialized_ms)),
                ("pipelined_step_per_task", num(pipelined_ms / task_ms)),
                ("speedup", num(overlap_speedup)),
            ]),
        ),
        (
            "calibration",
            obj(vec![
                ("calib_scale", num(calib.train.calib_scale)),
                ("calib_epochs", num(calib.train.calib_epochs as f64)),
                ("makespan_drift", num(calib.train.makespan_drift)),
                ("encode_buf_fresh", num(calib.encode_buf_fresh as f64)),
                ("encode_buf_reused", num(calib.encode_buf_reused as f64)),
            ]),
        ),
        (
            "tracing",
            obj(vec![
                ("untraced_mean_step_ms", num(untraced_ms)),
                ("traced_mean_step_ms", num(traced_ms)),
                ("overhead_ratio", num(trace_overhead)),
            ]),
        ),
        ("overlap_threads_sweep", arr(sweep)),
    ]);
    let path = "BENCH_dist_step.json";
    std::fs::write(path, report.to_string_pretty()).expect("writing bench report");
    println!("wrote {path}");
}
