//! Bench: the XLA-backend hot path — one fused trainstep execute (fwd +
//! bwd + SGD under masks), the score probe, the eval pass, and the full
//! coordinator batch (schedule + 5 steps + accounting).
//!
//! Requires the `xla` feature + artifacts; without the feature it
//! prints a note and exits (the artifact-free analogue is
//! `benches/native_step.rs`).

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "e2e_step bench requires --features xla; see benches/native_step.rs for the native path"
    );
}

#[cfg(feature = "xla")]
fn main() {
    use d2ft::cluster::CostModel;
    use d2ft::data::{Batcher, DatasetSpec, SyntheticKind};
    use d2ft::partition::Partition;
    use d2ft::runtime::{ArtifactRegistry, ParamStore, Session, TrainState};
    use d2ft::schedule::bilevel::BiLevel;
    use d2ft::schedule::{Budget, MaskPair, Scheduler};
    use d2ft::scores::{ScoreBook, ScoreConfig};
    use d2ft::tensor::Tensor;

    let registry = match ArtifactRegistry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping e2e bench (no artifacts): {e}");
            return;
        }
    };
    let manifest = &registry.full_manifest;
    let mc = manifest.config.clone();
    let mb = manifest.micro_batch;
    let session = Session::new(&registry, manifest).unwrap();
    let store = ParamStore::load(manifest, registry.dir()).unwrap();
    let mut state = TrainState::new(&store).unwrap();
    let part = Partition::per_head(&mc);

    let data =
        DatasetSpec::preset(SyntheticKind::Cifar100Like, mc.img_size, 5 * mb, 7).generate("train");
    let mut batcher = Batcher::new(&data, mb, 5, 3);
    let micros = batcher.next_batch().unwrap();
    let lits: Vec<(xla::Literal, xla::Literal)> = micros
        .iter()
        .map(|(x, y)| (session.x_literal(x).unwrap(), session.y_literal(y).unwrap()))
        .collect();
    let ones = MaskPair::ones(mc.depth, mc.heads);

    // warmup + compile
    session.step(&mut state, &lits[0].0, &lits[0].1, &ones, 0.01).unwrap();
    session.eval(&state, &lits[0].0, &lits[0].1, None).unwrap();
    session.probe_scores(&state, &lits[0].0, &lits[0].1).unwrap();

    let time = |label: &str, mut f: Box<dyn FnMut() + '_>| {
        let reps = 5usize;
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        println!("e2e {label:<28} best {best:>10.2}ms");
        best
    };

    let step_ms = time(
        "trainstep (p_f, fused)",
        Box::new(|| {
            session.step(&mut state, &lits[0].0, &lits[0].1, &ones, 0.01).unwrap();
        }),
    );
    time(
        "eval (p_o forward)",
        Box::new(|| {
            session.eval(&state, &lits[0].0, &lits[0].1, None).unwrap();
        }),
    );
    time(
        "score probe",
        Box::new(|| {
            session.probe_scores(&state, &lits[0].0, &lits[0].1).unwrap();
        }),
    );

    // full coordinator batch: probe-free steady state (scores cached)
    let probes: Vec<Tensor> = lits
        .iter()
        .map(|(x, y)| session.probe_scores(&state, x, y).unwrap())
        .collect();
    let book = ScoreBook::from_probes(&part, &probes);
    let mut sched = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    let budget = Budget::uniform(5, 3, 1);
    let batch_ms = time(
        "coordinator batch (5 steps)",
        Box::new(|| {
            let table = sched.schedule(&book, &budget);
            for (i, (x, y)) in lits.iter().enumerate() {
                let masks = table.masks_for_micro(&part, i);
                session.step(&mut state, x, y, &masks, 0.01).unwrap();
            }
        }),
    );
    let overhead = (batch_ms - 5.0 * step_ms) / batch_ms * 100.0;
    println!("e2e coordinator overhead        {overhead:>9.1}% of batch (target < 5%)");
}
