//! Bench: the multi-tenant fine-tuning service under open-loop job
//! arrivals — sustained jobs/sec, fleet-wide step-latency percentiles,
//! per-tenant adapter bytes against the dense swap baseline, and a
//! bitwise co-tenancy isolation check. Artifact-free; writes
//! `BENCH_serve_jobs.json`.
//!
//!     cargo bench --bench serve_jobs
//!
//! Asserts the headline claims:
//! * K=2 replicas sustain >= 3 concurrent tenant jobs (mixed LoRA
//!   ranks, budgets, and step quotas) to completion;
//! * every job ships adapter-sized state only: metered bytes are
//!   non-zero and the per-job `adapter_savings` against the dense
//!   params+momentum baseline stays above 50%;
//! * a job trained under co-tenancy is *bitwise* identical to the same
//!   spec run alone in its own service (the hot-swap protocol leaks
//!   nothing between tenants).

#[cfg(not(feature = "native"))]
fn main() {
    eprintln!("serve_jobs bench requires the default `native` feature");
}

#[cfg(feature = "native")]
fn main() {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use d2ft::config::JobSpec;
    use d2ft::obs::Registry;
    use d2ft::serve::{serve, ServeConfig};
    use d2ft::util::json::{arr, num, obj, s};

    const WORKERS: usize = 2;
    const WAIT: Duration = Duration::from_secs(600);

    // The arrival plan: 6 jobs over 4 tenants, mixed ranks / budgets /
    // quotas, inter-arrival gaps from a fixed LCG (open loop — arrivals
    // never wait for completions, so admission sees real contention:
    // 6 jobs of >= 20 micro-steps per round against 2 x 32-micro bins).
    let mut lcg: u64 = 0x5EED_CAFE;
    let mut gap_ms = || {
        lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        5 + (lcg >> 33) % 40
    };
    let plan: Vec<JobSpec> = [
        ("acme", 11u64, 2usize, 8usize, 3usize, 1usize),
        ("birch", 22, 4, 8, 3, 1),
        ("cedar", 33, 2, 4, 2, 2),
        ("acme", 44, 1, 8, 3, 0),
        ("doyle", 55, 8, 4, 3, 1),
        ("birch", 66, 2, 12, 2, 1),
    ]
    .iter()
    .map(|&(tenant, seed, rank, batches, n_full, n_fwd)| {
        let mut sp = JobSpec::default_for(tenant);
        sp.seed = seed;
        sp.lora_rank = rank;
        sp.batches = batches;
        sp.budget_full = n_full;
        sp.budget_fwd = n_fwd;
        sp.pretrain_batches = 1;
        sp
    })
    .collect();

    let registry = Arc::new(Registry::new());
    let mut cfg = ServeConfig::new();
    cfg.workers = WORKERS;
    cfg.max_tenants = 4;
    cfg.metrics = Some(Arc::clone(&registry));
    let mut handle = serve(cfg).expect("service");

    println!("open-loop arrivals: {} jobs over 4 tenants on {WORKERS} replicas", plan.len());
    let t0 = Instant::now();
    let mut ids = Vec::new();
    let mut peak_in_flight = 0usize;
    for spec in &plan {
        std::thread::sleep(Duration::from_millis(gap_ms()));
        let id = handle.submit(spec).expect("submit");
        ids.push(id);
        let in_flight = ids
            .iter()
            .filter(|&&j| {
                let st = handle.report(j).expect("known job").state;
                st == "queued" || st == "running" || st == "preempted"
            })
            .count();
        peak_in_flight = peak_in_flight.max(in_flight);
        println!(
            "  t+{:>5.0}ms submit job {id} {:<5} rank {} x {} batches ({in_flight} in flight)",
            t0.elapsed().as_secs_f64() * 1e3,
            spec.tenant,
            spec.lora_rank,
            spec.batches
        );
    }

    let reports: Vec<_> = ids
        .iter()
        .map(|&id| handle.wait(id, WAIT).expect("job terminates"))
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    let jobs_per_sec = reports.len() as f64 / wall_s;

    // --- claims ------------------------------------------------------------
    for r in &reports {
        assert_eq!(r.state, "completed", "job {} ({}) failed: {}", r.job_id, r.tenant, r.error);
        assert_eq!(r.batches_done, r.batches_quota, "job {} under-ran its quota", r.job_id);
        assert!(r.bytes_up > 0 && r.bytes_down > 0, "job {} metered no bytes", r.job_id);
        assert!(
            r.adapter_savings > 0.5,
            "job {}: adapter swap should beat the dense baseline (savings {:.3})",
            r.job_id,
            r.adapter_savings
        );
    }
    assert!(
        peak_in_flight >= 3,
        "open-loop plan must overlap >= 3 tenant jobs (peak {peak_in_flight})"
    );

    // Fleet-wide step latency straight from the service's histogram.
    let hist = registry.histogram("serve_step_ms");
    let (p50, p99) = (hist.percentile(0.50), hist.percentile(0.99));
    let total_batches: usize = reports.iter().map(|r| r.batches_done).sum();
    assert_eq!(hist.count() as usize, total_batches, "one latency sample per batch");

    // Bitwise co-tenancy isolation: re-run the most contended spec
    // alone in a fresh single-tenant service and compare adapter state.
    let probe = ids[0];
    let shared_state = handle.final_state(probe).expect("completed job exports state");
    let mut solo = serve(ServeConfig::new()).expect("solo service");
    let solo_id = solo.submit(&plan[0]).expect("solo submit");
    solo.wait(solo_id, WAIT).expect("solo terminates");
    let solo_state = solo.final_state(solo_id).expect("solo state");
    solo.shutdown();
    assert_eq!(
        shared_state, solo_state,
        "co-tenancy must be bitwise invisible in the trained adapter"
    );
    println!("bitwise isolation OK (job {probe} vs solo run)");

    let sum_up: u64 = reports.iter().map(|r| r.bytes_up).sum();
    let sum_down: u64 = reports.iter().map(|r| r.bytes_down).sum();
    let mean_savings: f64 =
        reports.iter().map(|r| r.adapter_savings).sum::<f64>() / reports.len() as f64;
    println!(
        "{} jobs in {wall_s:.2}s -> {jobs_per_sec:.2} jobs/s | step p50 {p50:.2}ms p99 \
         {p99:.2}ms | adapter bytes {sum_up} up / {sum_down} down ({:.1}% saved vs dense)",
        reports.len(),
        mean_savings * 100.0
    );

    // --- artifact ----------------------------------------------------------
    let jobs: Vec<_> = reports
        .iter()
        .map(|r| {
            obj(vec![
                ("job_id", num(r.job_id as f64)),
                ("tenant", s(&r.tenant)),
                ("lora_rank", num(r.lora_rank as f64)),
                ("batches", num(r.batches_done as f64)),
                ("rounds", num(r.rounds as f64)),
                ("bytes_up", num(r.bytes_up as f64)),
                ("bytes_down", num(r.bytes_down as f64)),
                ("adapter_savings", num(r.adapter_savings)),
                ("step_ms_p50", num(r.step_ms_p50)),
                ("step_ms_p99", num(r.step_ms_p99)),
                ("wall_ms", num(r.wall_ms)),
            ])
        })
        .collect();
    let report = obj(vec![
        ("schema", s("d2ft-bench-serve-jobs-v1")),
        ("workers", num(WORKERS as f64)),
        ("jobs", num(reports.len() as f64)),
        ("peak_in_flight", num(peak_in_flight as f64)),
        ("wall_s", num(wall_s)),
        ("jobs_per_sec", num(jobs_per_sec)),
        ("step_ms_p50", num(p50)),
        ("step_ms_p99", num(p99)),
        ("bytes_up_total", num(sum_up as f64)),
        ("bytes_down_total", num(sum_down as f64)),
        ("mean_adapter_savings", num(mean_savings)),
        ("bitwise_isolation", num(1.0)),
        ("per_job", arr(jobs)),
    ]);
    handle.shutdown();
    let path = "BENCH_serve_jobs.json";
    std::fs::write(path, report.to_string_pretty()).expect("writing bench report");
    println!("wrote {path}");
    println!("serve_jobs bench OK");
}
