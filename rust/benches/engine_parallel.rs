//! Bench: serial vs parallel engine stepping on the synthetic workload.
//!
//! For K ∈ {2, 4, 8} simulated devices, runs the same scheduled workload
//! (D2FT bi-level over pseudo-scores, full simulation: spinning devices +
//! comm pipeline) through the serial reference path and the parallel
//! engine, and writes the comparison to `BENCH_engine_parallel.json`.
//! No artifacts required.
//!
//!     cargo bench --bench engine_parallel

use d2ft::cluster::{run_synthetic, ExecMode, SyntheticRunConfig};
use d2ft::util::json::{arr, num, obj, s, Json};

const BATCHES: usize = 24;
const REPS: usize = 5;

/// Best-of-REPS wall time (ms per step) plus the final report's modeled
/// numbers (identical across reps and modes by construction).
fn measure(devices: usize, mode: ExecMode) -> (f64, f64, f64) {
    let mut cfg = SyntheticRunConfig::quick(devices, mode);
    cfg.batches = BATCHES;
    let mut best_ms_per_step = f64::INFINITY;
    let mut makespan = 0.0;
    let mut saved = 0.0;
    for _ in 0..REPS {
        let r = run_synthetic(&cfg);
        best_ms_per_step = best_ms_per_step.min(r.wall_s * 1e3 / BATCHES as f64);
        makespan = r.mean_makespan_ms;
        saved = r.comm_saved_ms;
    }
    (best_ms_per_step, makespan, saved)
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("engine_parallel: {BATCHES} batches/run, best of {REPS}, {cores} core(s)");
    let mut entries = Vec::new();
    let mut speedup_at_8 = 0.0;
    for &k in &[2usize, 4, 8] {
        let (serial_ms, makespan_ms, saved_ms) = measure(k, ExecMode::Serial);
        let (parallel_ms, _, _) = measure(k, ExecMode::Parallel { workers: 0 });
        let speedup = serial_ms / parallel_ms;
        if k == 8 {
            speedup_at_8 = speedup;
        }
        println!(
            "bench engine K={k:<2} serial {serial_ms:>8.3}ms/step  \
             parallel {parallel_ms:>8.3}ms/step  speedup {speedup:>5.2}x  \
             (modeled makespan {makespan_ms:.2}ms, comm overlap saves {saved_ms:.2}ms)"
        );
        entries.push(obj(vec![
            ("devices", num(k as f64)),
            ("serial_ms_per_step", num(serial_ms)),
            ("parallel_ms_per_step", num(parallel_ms)),
            ("speedup", num(speedup)),
            ("modeled_makespan_ms", num(makespan_ms)),
            ("comm_overlap_saved_ms", num(saved_ms)),
        ]));
    }
    let report = obj(vec![
        ("bench", s("engine_parallel")),
        ("batches_per_run", num(BATCHES as f64)),
        ("reps", num(REPS as f64)),
        ("host_cores", num(cores as f64)),
        ("parallel_faster_at_k8", Json::Bool(speedup_at_8 > 1.0)),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_engine_parallel.json";
    std::fs::write(path, report.to_string_pretty()).expect("writing bench report");
    println!("wrote {path}");
    if speedup_at_8 <= 1.0 {
        eprintln!(
            "WARNING: parallel not faster than serial at K=8 \
             (speedup {speedup_at_8:.2}x; single-core host?)"
        );
    }
}
