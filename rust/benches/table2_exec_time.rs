//! Bench: paper Table II — per-subnet batch execution time under each
//! scheduling method, via the V100-calibrated exec-time model (makespan
//! + mean device time) on the 72-subnet instance.

use std::time::Duration;

use d2ft::cluster::{CostModel, ExecTimeModel};
use d2ft::partition::Partition;
use d2ft::runtime::ModelConfig;
use d2ft::schedule::bilevel::BiLevel;
use d2ft::schedule::dpruning::DPruning;
use d2ft::schedule::moe_gshard::MoeGshard;
use d2ft::schedule::random_sched::RandomSched;
use d2ft::schedule::{Budget, Scheduler};
use d2ft::scores::{Metric, ScoreBook, ScoreConfig};
use d2ft::util::bench::{black_box, Bench};
use d2ft::util::rng::Rng;

fn main() {
    let cfg = ModelConfig {
        img_size: 224, patch: 16, dim: 384, depth: 12, heads: 6,
        mlp_ratio: 4, classes: 196, lora_rank: 0, head_dim: 64, tokens: 197,
    };
    let part = Partition::per_head(&cfg);
    let mut rng = Rng::new(2);
    let mut book = ScoreBook::zeros(part.n_subnets(), 5);
    for k in 0..part.n_subnets() {
        for i in 0..5 {
            for m in [Metric::Fisher, Metric::GradMag, Metric::Taylor, Metric::WeightMag] {
                book.set(m, k, i, rng.next_f64() * 10.0);
            }
        }
    }
    let budget = Budget::uniform(5, 3, 0); // the paper's 60% setting
    let model = ExecTimeModel::paper();

    let mut methods: Vec<(&str, Box<dyn Scheduler>)> = vec![
        ("D2FT (Ours)", Box::new(BiLevel::new(ScoreConfig::default(), CostModel::paper()))),
        ("Random", Box::new(RandomSched::new(7))),
        ("DPruning M/G", Box::new(DPruning::magnitude_gradient())),
        ("DPruning M", Box::new(DPruning::magnitude())),
        ("MoE Gshard", Box::new(MoeGshard::new(9, 6))),
    ];
    println!("Table II analogue (V100-calibrated model, 60% budget):");
    println!("{:<14} {:>12} {:>16}", "method", "makespan", "mean device");
    for (name, sched) in methods.iter_mut() {
        let table = sched.schedule(&book, &budget);
        println!(
            "{:<14} {:>10.2}ms {:>14.2}ms",
            name,
            model.makespan_ms(&table),
            model.mean_device_time_ms(&table)
        );
    }
    // And the wall-clock cost of the accounting itself:
    let mut d2ft = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    let table = d2ft.schedule(&book, &budget);
    Bench::new("exec-time-makespan-72")
        .target_time(Duration::from_millis(400))
        .run(|| black_box(model.makespan_ms(&table)))
        .report();
}
