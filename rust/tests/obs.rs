//! The observability layer's two contracts, end to end:
//!
//! 1. **Observation-only** — arming the step tracer and the metrics
//!    registry must not change the numerics: a traced dist run produces
//!    a bitwise identical loss trajectory and final parameters to an
//!    untraced one.
//! 2. **Artifact shape** — the merged `--trace-out` document is valid
//!    Chrome trace-event JSON: per-lane `process_name` metadata for the
//!    aggregator and every worker, compute/step spans with durations,
//!    and the registry exposes the wire/step-latency series the CI
//!    scrape asserts on.
//!
//! Everything runs in ONE test function: the trace recorder is
//! process-global, and the integration-test harness runs `#[test]`s in
//! parallel threads — a second armed run in this binary would bleed
//! events into the first run's drain.
#![cfg(feature = "native")]

use std::collections::BTreeSet;
use std::sync::Arc;

use d2ft::backend::native::{NativeProvider, NativeSpec};
use d2ft::coordinator::{SchedulerKind, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::dist::{DistConfig, DistTrainer};
use d2ft::obs::Registry;
use d2ft::runtime::ModelConfig;
use d2ft::schedule::Budget;
use d2ft::util::json::Json;

fn small_provider() -> NativeProvider {
    let spec = NativeSpec::builder()
        .config(ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        })
        .micro_batch(2)
        .mb_variants(vec![])
        .lora_ranks(vec![2])
        .lora_standard_rank(2)
        .init_seed(0x0B5)
        .threads(1)
        .build()
        .expect("obs spec");
    NativeProvider::new(spec)
}

fn cfg() -> TrainerConfig {
    let mut c = TrainerConfig::quick(
        SyntheticKind::Cifar10Like,
        SchedulerKind::D2ft,
        Budget::uniform(5, 3, 1),
    );
    c.train_size = 80;
    c.test_size = 16;
    c.batches = 3;
    c.pretrain_batches = 1;
    c.update = UpdateMode::BatchAccum;
    c
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn tracing_and_metrics_are_observation_only_and_artifact_is_well_formed() {
    let provider = small_provider();

    // Reference: plain K=2 channel run, recorder disarmed.
    let mut plain = DistTrainer::new(&provider, DistConfig::new(cfg(), 2)).unwrap();
    let r_plain = plain.run().unwrap();
    let w_plain = plain.backend().param("b00_wqkv").unwrap();
    drop(plain);

    // Same run, fully observed: trace artifact + metrics registry.
    let trace_path =
        std::env::temp_dir().join(format!("d2ft_obs_trace_{}.json", std::process::id()));
    let registry = Arc::new(Registry::new());
    let dcfg = DistConfig::builder(cfg(), 2)
        .trace_out(Some(trace_path.clone()))
        .metrics(Some(Arc::clone(&registry)))
        .build()
        .expect("observed config");
    let mut traced = DistTrainer::new(&provider, dcfg).unwrap();
    let r_traced = traced.run().unwrap();
    let w_traced = traced.backend().param("b00_wqkv").unwrap();
    drop(traced);

    // --- contract 1: observation changed nothing -------------------
    assert_eq!(
        bits(&r_plain.train.loss_curve),
        bits(&r_traced.train.loss_curve),
        "tracing must not change the loss trajectory"
    );
    assert_eq!(
        r_plain.train.test_top1.to_bits(),
        r_traced.train.test_top1.to_bits(),
        "tracing must not change eval accuracy"
    );
    assert_eq!(w_plain, w_traced, "tracing must not change the final parameters");

    // --- contract 2a: the trace artifact is well-formed ------------
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let doc = Json::parse(&text).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "a traced run must record events");
    doc.get("truncatedEvents").unwrap().as_f64().unwrap();

    let mut lanes = BTreeSet::new();
    let mut named_lanes = BTreeSet::new();
    let mut cats = BTreeSet::new();
    let mut span_with_dur = 0usize;
    let mut last_ts = f64::NEG_INFINITY;
    for e in events {
        let ph = e.str_at("ph").unwrap();
        let pid = e.get("pid").unwrap().as_usize().unwrap();
        lanes.insert(pid);
        if ph == "M" {
            if e.str_at("name").unwrap() == "process_name" {
                named_lanes.insert(pid);
            }
            continue;
        }
        cats.insert(e.str_at("cat").unwrap());
        if ph == "X" {
            e.get("dur").unwrap().as_f64().unwrap();
            span_with_dur += 1;
        }
        // Non-metadata events are emitted sorted by normalized ts.
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "trace timestamps must be monotone after the merge");
        last_ts = ts;
    }
    // Aggregator lane plus one lane per worker, each named.
    for lane in [0usize, 1, 2] {
        assert!(lanes.contains(&lane), "missing lane {lane} (pids seen: {lanes:?})");
        assert!(named_lanes.contains(&lane), "lane {lane} has no process_name metadata");
    }
    assert!(span_with_dur > 0, "expected at least one completed span");
    for cat in ["compute", "step", "agg", "codec"] {
        assert!(cats.contains(cat), "expected category {cat:?} (saw: {cats:?})");
    }
    std::fs::remove_file(&trace_path).ok();

    // --- contract 2b: the registry carries the run's series --------
    assert!(
        registry.counter_value("d2ft_wire_up_bytes").unwrap() > 0,
        "uplink bytes must be published"
    );
    assert_eq!(
        registry.counter_value("d2ft_evictions_total"),
        Some(0),
        "a clean run publishes zero evictions"
    );
    assert_eq!(registry.gauge_value("d2ft_workers_live"), Some(2.0));
    let prom = registry.render_prometheus();
    for series in
        ["d2ft_step_latency_ms", "d2ft_socket_bytes_sent", "d2ft_wire_up_bytes", "quantile=\"0.9\""]
    {
        assert!(prom.contains(series), "Prometheus text must carry {series:?}:\n{prom}");
    }
    let json = registry.to_json();
    let hist = json.get("histograms").unwrap().get("d2ft_step_latency_ms").unwrap();
    assert_eq!(
        hist.get("count").unwrap().as_usize().unwrap(),
        3,
        "one step-latency sample per fine-tuning batch"
    );
}
