//! Feature-gated parity tests: the native and XLA backends must report
//! identical *scheduler-level* numbers (compute/comm fraction, workload
//! balance) for the same budget — and, started from a shared init blob
//! through `ParamStore`, must produce *comparable loss trajectories*
//! (same optimization, different FP association). Requires the `xla`
//! feature; skips cleanly when artifacts are absent.
#![cfg(all(feature = "xla", feature = "native"))]

use d2ft::backend::native::{NativeBackend, NativeProvider, NativeSpec};
use d2ft::backend::xla::XlaProvider;
use d2ft::backend::BackendProvider;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig};
use d2ft::data::SyntheticKind;
use d2ft::runtime::ParamStore;
use d2ft::schedule::Budget;

fn short_cfg() -> TrainerConfig {
    TrainerConfig::builder()
        .dataset(SyntheticKind::Cifar10Like)
        .scheduler(SchedulerKind::D2ft)
        .budget(Budget::uniform(5, 3, 1))
        .train_size(160)
        .test_size(32)
        .batches(3)
        .pretrain_batches(1)
        .build()
        .expect("short config")
}

#[test]
fn scheduler_level_metrics_agree_across_backends() {
    let Ok(xla) = XlaProvider::open_default() else {
        eprintln!("skipping backend parity test (run `make artifacts`)");
        return;
    };
    let native = NativeProvider::default();

    let run = |provider: &dyn BackendProvider| {
        let mut t = Trainer::new(provider, short_cfg()).unwrap();
        t.run().unwrap()
    };
    let rn = run(&native);
    let rx = run(&xla);

    // Backend-independent scheduler accounting (the device counts
    // differ between the two models, so compare the ratios).
    assert_eq!(rn.batches, rx.batches);
    assert!(
        (rn.compute_fraction - rx.compute_fraction).abs() < 1e-9,
        "budget accounting must agree: {} vs {}",
        rn.compute_fraction,
        rx.compute_fraction
    );
    assert!((rn.comm_fraction - rx.comm_fraction).abs() < 1e-9);
    assert_eq!(rn.workload_variance, 0.0, "D2FT balances exactly on native");
    assert_eq!(rx.workload_variance, 0.0, "D2FT balances exactly on xla");
    assert!((rn.compute_fraction - 0.68).abs() < 1e-9);

    // Backend-dependent numerics: both must train sanely.
    for r in [&rn, &rx] {
        assert!(r.final_train_loss.is_finite() && r.final_train_loss > 0.0);
        assert!(r.test_top1 >= 0.0 && r.test_top1 <= 1.0);
        assert_eq!(r.loss_curve.len(), 15);
    }
    assert_eq!(rn.backend, "native");
    assert_eq!(rx.backend, "xla");
    println!(
        "parity OK: compute {:.3} / comm {:.3} on both backends",
        rn.compute_fraction, rn.comm_fraction
    );
}

/// Numeric parity harness: import the XLA artifact set's init blob into
/// a native backend of the *same* model configuration, fine-tune both
/// from that shared initialization, and compare the loss trajectories —
/// not just scheduler metrics. The backends differ only in FP
/// association (fusion order), so the first loss must agree tightly and
/// the curves must track each other.
#[test]
fn loss_trajectories_track_from_shared_init() {
    let Ok(xla) = XlaProvider::open_default() else {
        eprintln!("skipping shared-init parity test (run `make artifacts`)");
        return;
    };
    let manifest = &xla.registry().full_manifest;
    let store = ParamStore::load(manifest, xla.registry().dir()).unwrap();

    // A native spec over the artifact set's exact model configuration;
    // parameter names/shapes mirror the manifest convention, so the
    // blob imports directly.
    let spec = NativeSpec::builder()
        .config(manifest.config.clone())
        .micro_batch(manifest.micro_batch)
        .mb_variants(manifest.mb_variants.clone())
        .lora_ranks(vec![])
        .lora_standard_rank(0)
        .init_seed(0)
        .threads(1)
        .build()
        .expect("parity spec");
    let mut native_be = NativeBackend::new(&spec, 0, manifest.micro_batch, 17);
    native_be
        .import_params(&store)
        .expect("native layout must accept the artifact init blob");

    let cfg = short_cfg();
    let mut tn = Trainer::with_backend(Box::new(native_be), cfg.clone()).unwrap();
    let rn = tn.run().unwrap();
    let mut tx = Trainer::new(&xla, cfg).unwrap();
    let rx = tx.run().unwrap();

    assert_eq!(rn.loss_curve.len(), rx.loss_curve.len());
    // Same parameters, same first micro-batch: only FP association
    // differs between the two compute paths.
    let (a0, b0) = (rn.loss_curve[0] as f64, rx.loss_curve[0] as f64);
    assert!(
        (a0 - b0).abs() / b0.abs().max(1e-6) < 0.02,
        "first losses should nearly coincide from shared init: {a0} vs {b0}"
    );
    // Trajectories track: mean relative gap stays small over the run.
    let mean_gap: f64 = rn
        .loss_curve
        .iter()
        .zip(&rx.loss_curve)
        .map(|(&a, &b)| ((a - b) as f64).abs() / (b as f64).abs().max(1e-6))
        .sum::<f64>()
        / rn.loss_curve.len() as f64;
    assert!(
        mean_gap < 0.35,
        "trajectories diverged from shared init: mean relative gap {mean_gap:.3}"
    );
    println!(
        "shared-init parity OK: first {a0:.4} vs {b0:.4}, mean relative gap {mean_gap:.3}"
    );
}
