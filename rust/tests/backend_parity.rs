//! Feature-gated parity smoke test: the native and XLA backends must
//! report identical *scheduler-level* numbers (compute/comm fraction,
//! workload balance) for the same budget, because those are properties
//! of the scheduling layer, not of the numerics. Requires the `xla`
//! feature; skips cleanly when artifacts are absent.
#![cfg(all(feature = "xla", feature = "native"))]

use d2ft::backend::native::NativeProvider;
use d2ft::backend::xla::XlaProvider;
use d2ft::backend::BackendProvider;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig};
use d2ft::data::SyntheticKind;
use d2ft::schedule::Budget;

fn short_cfg() -> TrainerConfig {
    TrainerConfig {
        train_size: 160,
        test_size: 32,
        batches: 3,
        pretrain_batches: 1,
        ..TrainerConfig::quick(
            SyntheticKind::Cifar10Like,
            SchedulerKind::D2ft,
            Budget::uniform(5, 3, 1),
        )
    }
}

#[test]
fn scheduler_level_metrics_agree_across_backends() {
    let Ok(xla) = XlaProvider::open_default() else {
        eprintln!("skipping backend parity test (run `make artifacts`)");
        return;
    };
    let native = NativeProvider::default();

    let run = |provider: &dyn BackendProvider| {
        let mut t = Trainer::new(provider, short_cfg()).unwrap();
        t.run().unwrap()
    };
    let rn = run(&native);
    let rx = run(&xla);

    // Backend-independent scheduler accounting (the device counts
    // differ between the two models, so compare the ratios).
    assert_eq!(rn.batches, rx.batches);
    assert!(
        (rn.compute_fraction - rx.compute_fraction).abs() < 1e-9,
        "budget accounting must agree: {} vs {}",
        rn.compute_fraction,
        rx.compute_fraction
    );
    assert!((rn.comm_fraction - rx.comm_fraction).abs() < 1e-9);
    assert_eq!(rn.workload_variance, 0.0, "D2FT balances exactly on native");
    assert_eq!(rx.workload_variance, 0.0, "D2FT balances exactly on xla");
    assert!((rn.compute_fraction - 0.68).abs() < 1e-9);

    // Backend-dependent numerics: both must train sanely.
    for r in [&rn, &rx] {
        assert!(r.final_train_loss.is_finite() && r.final_train_loss > 0.0);
        assert!(r.test_top1 >= 0.0 && r.test_top1 <= 1.0);
        assert_eq!(r.loss_curve.len(), 15);
    }
    assert_eq!(rn.backend, "native");
    assert_eq!(rx.backend, "xla");
    println!(
        "parity OK: compute {:.3} / comm {:.3} on both backends",
        rn.compute_fraction, rn.comm_fraction
    );
}
