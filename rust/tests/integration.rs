//! Artifact-free integration tests: scheduler x cluster x partition
//! pipelines over synthetic scores (no PJRT required, so these run in
//! any environment).

use d2ft::cluster::{CostModel, ExecTimeModel, HeteroSpec, WorkloadTracker};
use d2ft::partition::Partition;
use d2ft::runtime::ModelConfig;
use d2ft::schedule::bilevel::BiLevel;
use d2ft::schedule::dpruning::DPruning;
use d2ft::schedule::moe_gshard::MoeGshard;
use d2ft::schedule::random_sched::RandomSched;
use d2ft::schedule::scaler::{Lambda, ScalerSched};
use d2ft::schedule::{Budget, Op, Scheduler};
use d2ft::scores::{Metric, ScoreBook, ScoreConfig};
use d2ft::util::rng::Rng;

fn vit_small_cfg() -> ModelConfig {
    // the paper's exact topology: 12 blocks x 6 heads = 72 body subnets
    ModelConfig {
        img_size: 224, patch: 16, dim: 384, depth: 12, heads: 6,
        mlp_ratio: 4, classes: 196, lora_rank: 0, head_dim: 64, tokens: 197,
    }
}

fn random_book(part: &Partition, n_micro: usize, seed: u64) -> ScoreBook {
    let mut rng = Rng::new(seed);
    let mut book = ScoreBook::zeros(part.n_subnets(), n_micro);
    for k in 0..part.n_subnets() {
        let wm = rng.next_f64() * 3.0 + 0.5; // per-subnet, sample-invariant
        for i in 0..n_micro {
            book.set(Metric::Fisher, k, i, rng.next_f64() * 10.0);
            book.set(Metric::GradMag, k, i, rng.next_f64() * 4.0);
            book.set(Metric::Taylor, k, i, rng.next_f64() * 2.0);
            book.set(Metric::WeightMag, k, i, wm);
        }
    }
    book
}

/// Paper Table I shape: D2FT variance exactly 0, baselines > 0.
#[test]
fn table1_shape_d2ft_zero_variance_baselines_positive() {
    let part = Partition::per_head(&vit_small_cfg());
    let book = random_book(&part, 5, 42);
    let budget = Budget::uniform(5, 3, 0);
    let cost = CostModel::paper();

    let variance_of = |sched: &mut dyn Scheduler| -> f64 {
        let mut w = WorkloadTracker::new(cost, part.n_subnets());
        for _ in 0..4 {
            w.record(&sched.schedule(&book, &budget));
        }
        w.workload_variance()
    };

    let mut d2ft = BiLevel::new(ScoreConfig::default(), cost);
    assert_eq!(variance_of(&mut d2ft), 0.0, "D2FT must balance exactly");

    let mut random = RandomSched::new(7);
    assert!(variance_of(&mut random) > 0.0);
    let mut dp = DPruning::magnitude();
    assert!(variance_of(&mut dp) > 0.15);
    let mut dpg = DPruning::magnitude_gradient();
    assert!(variance_of(&mut dpg) > 0.15);
    let mut moe = MoeGshard::new(3, 6);
    assert!(variance_of(&mut moe) > 0.0);
}

/// Paper Table II shape: balanced schedules have lower makespan than
/// imbalanced ones at the same average budget.
#[test]
fn table2_shape_d2ft_makespan_beats_pruning() {
    let part = Partition::per_head(&vit_small_cfg());
    let book = random_book(&part, 5, 43);
    let budget = Budget::uniform(5, 3, 0);
    let cost = CostModel::paper();
    let model = ExecTimeModel::paper();

    let mut d2ft = BiLevel::new(ScoreConfig::default(), cost);
    let t_d2ft = d2ft.schedule(&book, &budget);
    let mut dp = DPruning::magnitude();
    let t_dp = dp.schedule(&book, &budget);

    let mk_d2ft = model.makespan_ms(&t_d2ft);
    let mk_dp = model.makespan_ms(&t_dp);
    assert!(
        mk_d2ft < mk_dp,
        "balanced D2FT makespan {mk_d2ft} must beat all-or-nothing pruning {mk_dp}"
    );
    // MoE processes fewer samples -> lower time (the paper's caveat).
    let mut moe = MoeGshard::new(11, 6);
    let t_moe = moe.schedule(&book, &budget);
    let processed_moe: usize = (0..t_moe.n_subnets)
        .map(|k| 5 - t_moe.count_row(k, Op::Shortcut))
        .sum();
    let processed_d2ft: usize = (0..t_d2ft.n_subnets)
        .map(|k| 5 - t_d2ft.count_row(k, Op::Shortcut))
        .sum();
    assert!(processed_moe < processed_d2ft);
}

/// Budget sweep: compute/comm fractions land on the paper's settings.
#[test]
fn budget_cost_accounting_matches_paper_points() {
    let part = Partition::per_head(&vit_small_cfg());
    let book = random_book(&part, 5, 44);
    let cost = CostModel::paper();
    for (budget, expect_compute, expect_comm) in [
        (Budget::uniform(5, 3, 0), 0.6, 0.6),
        (Budget::uniform(5, 3, 1), 0.68, 0.7),
        (Budget::uniform(5, 2, 1), 0.48, 0.5),
        (Budget::uniform(5, 3, 2), 0.76, 0.8),
    ] {
        let mut d2ft = BiLevel::new(ScoreConfig::default(), cost);
        let t = d2ft.schedule(&book, &budget);
        let mut w = WorkloadTracker::new(cost, part.n_subnets());
        w.record(&t);
        assert!(
            (w.total_compute_fraction() - expect_compute).abs() < 1e-9,
            "compute {} != {expect_compute}",
            w.total_compute_fraction()
        );
        assert!(
            (w.total_comm_fraction() - expect_comm).abs() < 1e-9,
            "comm {} != {expect_comm}",
            w.total_comm_fraction()
        );
    }
}

/// D2FT picks strictly better-scoring micro-batches than Random under
/// the same budget (the mechanism behind the accuracy gap).
#[test]
fn d2ft_captures_more_contribution_than_random() {
    let part = Partition::per_head(&vit_small_cfg());
    let book = random_book(&part, 5, 45);
    let budget = Budget::uniform(5, 2, 1);
    let cost = CostModel::paper();
    let captured = |t: &d2ft::schedule::ScheduleTable| -> f64 {
        let mut total = 0.0;
        for k in 0..t.n_subnets {
            for i in 0..t.n_micro {
                match t.get(k, i) {
                    Op::Full => total += book.get(Metric::WeightMag, k, i),
                    Op::ForwardOnly => total += book.get(Metric::Fisher, k, i),
                    Op::Shortcut => {}
                }
            }
        }
        total
    };
    let mut d2ft_s = BiLevel::new(ScoreConfig::default(), cost);
    let c_d2ft = captured(&d2ft_s.schedule(&book, &budget));
    let mut rnd = RandomSched::new(5);
    let c_rnd = captured(&rnd.schedule(&book, &budget));
    assert!(c_d2ft > c_rnd, "D2FT {c_d2ft} must capture more than Random {c_rnd}");
}

/// Scaler-Max approximates bi-level; Scaler-Min diverges (Table X shape).
#[test]
fn table10_shape_scaler_max_close_min_far() {
    let part = Partition::per_head(&vit_small_cfg());
    let book = random_book(&part, 5, 46);
    let budget = Budget::uniform(5, 2, 2);
    let cost = CostModel::paper();
    let mut bi = BiLevel::new(ScoreConfig::default(), cost);
    let t_bi = bi.schedule(&book, &budget);
    let mut mx = ScalerSched::new(Lambda::Max, ScoreConfig::default(), cost);
    let t_mx = mx.schedule(&book, &budget);
    let mut mn = ScalerSched::new(Lambda::Min, ScoreConfig::default(), cost);
    let t_mn = mn.schedule(&book, &budget);

    let agreement = |a: &d2ft::schedule::ScheduleTable, b: &d2ft::schedule::ScheduleTable| -> f64 {
        let mut same = 0;
        let mut full_total = 0;
        for k in 0..a.n_subnets {
            for i in 0..a.n_micro {
                if a.get(k, i) == Op::Full {
                    full_total += 1;
                    if b.get(k, i) == Op::Full {
                        same += 1;
                    }
                }
            }
        }
        same as f64 / full_total.max(1) as f64
    };
    let agree_max = agreement(&t_bi, &t_mx);
    let agree_min = agreement(&t_bi, &t_mn);
    assert!(
        agree_max > agree_min,
        "Max-scaler p_f agreement {agree_max} must exceed Min {agree_min}"
    );
}

/// Heterogeneity wiring: overridden devices get their budget.
#[test]
fn hetero_budget_and_partition_integration() {
    let cfg = vit_small_cfg();
    let spec = HeteroSpec::compute(9);
    let part = spec.partition(&cfg);
    assert_eq!(part.n_subnets(), 72);
    let budget = spec.budget(Budget::uniform(5, 2, 2), part.n_subnets());
    let book = random_book(&part, 5, 47);
    let mut d2ft = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    let t = d2ft.schedule(&book, &budget);
    for k in 0..9 {
        assert_eq!(t.count_row(k, Op::Full), 3, "fast device {k}");
        assert_eq!(t.count_row(k, Op::ForwardOnly), 1);
    }
    for k in 9..72 {
        assert_eq!(t.count_row(k, Op::Full), 2, "slow device {k}");
        assert_eq!(t.count_row(k, Op::ForwardOnly), 2);
    }
    // memory heterogeneity: merged partition still covers the model
    let mem = HeteroSpec::memory(14).partition(&cfg);
    mem.validate().unwrap();
    assert_eq!(mem.n_subnets(), 72 - 14);
}

/// Masks built from a schedule drive the (L, H) grid coherently across
/// partition granularities (Table V wiring).
#[test]
fn table5_wiring_masks_consistent_across_granularity() {
    let cfg = vit_small_cfg();
    for group in [1usize, 2, 3, 6] {
        let part = Partition::grouped(&cfg, group);
        let book = random_book(&part, 5, 48);
        let mut d2ft = BiLevel::new(ScoreConfig::default(), CostModel::paper());
        let t = d2ft.schedule(&book, &Budget::uniform(5, 2, 2));
        for i in 0..5 {
            let m = t.masks_for_micro(&part, i);
            // every (l, h) cell is covered by exactly one subnet: fwd
            // mask is 0/1 and bwd <= fwd.
            for l in 0..cfg.depth {
                for h in 0..cfg.heads {
                    let f = m.fwd.at(&[l, h]);
                    let b = m.bwd.at(&[l, h]);
                    assert!(f == 0.0 || f == 1.0);
                    assert!(b <= f, "bwd mask must imply fwd mask");
                }
            }
        }
    }
}
