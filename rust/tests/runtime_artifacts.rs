//! Runtime integration tests against the real AOT artifacts.
//!
//! Requires the `xla` feature (the whole file compiles away without it)
//! and `make artifacts`; skips cleanly when artifacts are absent. All
//! checks run inside ONE #[test] so the expensive XLA compilation
//! happens once per binary (the registry caches compiled executables
//! per process).
#![cfg(feature = "xla")]

use d2ft::runtime::{ArtifactRegistry, ParamStore, Session, TrainState};
use d2ft::schedule::MaskPair;
use d2ft::tensor::Tensor;

fn sample_batch(mc: &d2ft::runtime::ModelConfig, mb: usize, seed: u64) -> (Tensor, Vec<i32>) {
    let d = d2ft::data::DatasetSpec::preset(
        d2ft::data::SyntheticKind::Cifar100Like,
        mc.img_size,
        mb,
        seed,
    )
    .generate("train");
    d.gather(&(0..mb).collect::<Vec<_>>())
}

#[test]
fn artifact_runtime_suite() {
    let Ok(reg) = ArtifactRegistry::open_default() else {
        eprintln!("skipping artifact tests (run `make artifacts`)");
        return;
    };
    check_manifest_and_params(&reg);
    check_trainstep_loss_and_masks(&reg);
    check_bwd_mask_freezes_subnet(&reg);
    check_fwd_mask_changes_eval(&reg);
    check_score_probe(&reg);
    check_lora(&reg);
}

fn check_manifest_and_params(reg: &ArtifactRegistry) {
    let m = &reg.full_manifest;
    let store = ParamStore::load(m, reg.dir()).unwrap();
    assert_eq!(store.n_tensors(), m.n_params());
    assert_eq!(store.total_elems(), m.total_elems);
    // norm scales init to 1.0 -> abs sum of a ln_g equals dim
    let g = store.tensor("b00_ln1_g").unwrap();
    assert_eq!(g.len(), m.config.dim);
    assert!((g.sum() - m.config.dim as f32).abs() < 1e-3);
    // literals round-trip exactly
    let mut store2 = ParamStore::zeros_like(m);
    let lits = store.to_literals().unwrap();
    store2.from_literals(&lits).unwrap();
    assert_eq!(store.slice("z_head_w"), store2.slice("z_head_w"));
    println!("manifest/params OK");
}

fn check_trainstep_loss_and_masks(reg: &ArtifactRegistry) {
    let m = &reg.full_manifest;
    let mc = &m.config;
    let session = Session::new(reg, m).unwrap();
    let store = ParamStore::load(m, reg.dir()).unwrap();
    let mut state = TrainState::new(&store).unwrap();
    let (xt, yt) = sample_batch(mc, m.micro_batch, 3);
    let x = session.x_literal(&xt).unwrap();
    let y = session.y_literal(&yt).unwrap();

    // lr = 0: params unchanged, loss ~= ln(classes) at init.
    let ones = MaskPair::ones(mc.depth, mc.heads);
    let out = session.step(&mut state, &x, &y, &ones, 0.0).unwrap();
    assert!(
        (out.loss - (mc.classes as f32).ln()).abs() < 1.0,
        "init loss {} vs ln(C) {}",
        out.loss,
        (mc.classes as f32).ln()
    );
    let mut store_after = ParamStore::zeros_like(m);
    state.write_back(&mut store_after).unwrap();
    assert_eq!(
        store.slice("z_head_w"),
        store_after.slice("z_head_w"),
        "lr=0 must not move params"
    );

    // same micro-batch, full masks, positive lr: loss decreases.
    let first = session.step(&mut state, &x, &y, &ones, 0.05).unwrap().loss;
    let mut last = first;
    for _ in 0..4 {
        last = session.step(&mut state, &x, &y, &ones, 0.05).unwrap().loss;
    }
    assert!(last < first, "loss should fall on repeated batch: {first} -> {last}");

    // eval agrees with trainstep's loss at lr=0 (same forward).
    let ev = session.eval(&state, &x, &y, None).unwrap();
    let tr = session.step(&mut state, &x, &y, &ones, 0.0).unwrap();
    assert!((ev.loss - tr.loss).abs() < 1e-4, "eval {} vs trainstep {}", ev.loss, tr.loss);
    println!("trainstep/eval OK");
}

fn check_bwd_mask_freezes_subnet(reg: &ArtifactRegistry) {
    let m = &reg.full_manifest;
    let mc = &m.config;
    let session = Session::new(reg, m).unwrap();
    let store = ParamStore::load(m, reg.dir()).unwrap();
    let mut state = TrainState::new(&store).unwrap();
    let (xt, yt) = sample_batch(mc, m.micro_batch, 4);
    let x = session.x_literal(&xt).unwrap();
    let y = session.y_literal(&yt).unwrap();

    // p_o on subnet (block 1, head 2): its qkv slice must stay frozen.
    let mut masks = MaskPair::ones(mc.depth, mc.heads);
    masks.bwd.set(&[1, 2], 0.0);
    session.step(&mut state, &x, &y, &masks, 0.1).unwrap();
    let mut after = ParamStore::zeros_like(m);
    state.write_back(&mut after).unwrap();

    let before_q = store.slice("b01_wqkv").unwrap();
    let after_q = after.slice("b01_wqkv").unwrap();
    let d = mc.dim;
    let (heads, dh) = (mc.heads, mc.head_dim);
    let mut frozen_diff = 0.0f32;
    let mut other_diff = 0.0f32;
    // wqkv row-major [D, 3D]; head h's column block within each of the
    // 3 projections: cols [p*D + h*dh, p*D + (h+1)*dh).
    for r in 0..d {
        for p in 0..3 {
            for h in 0..heads {
                for c in 0..dh {
                    let col = p * d + h * dh + c;
                    let delta = (after_q[r * 3 * d + col] - before_q[r * 3 * d + col]).abs();
                    if h == 2 {
                        frozen_diff += delta;
                    } else {
                        other_diff += delta;
                    }
                }
            }
        }
    }
    assert_eq!(frozen_diff, 0.0, "p_o subnet must not update");
    assert!(other_diff > 0.0, "other subnets must update");
    println!("bwd-mask freeze OK");
}

fn check_fwd_mask_changes_eval(reg: &ArtifactRegistry) {
    let m = &reg.full_manifest;
    let mc = &m.config;
    let session = Session::new(reg, m).unwrap();
    let store = ParamStore::load(m, reg.dir()).unwrap();
    let state = TrainState::new(&store).unwrap();
    let (xt, yt) = sample_batch(mc, m.micro_batch, 5);
    let x = session.x_literal(&xt).unwrap();
    let y = session.y_literal(&yt).unwrap();
    let full = session.eval(&state, &x, &y, None).unwrap();
    let mut partial_mask = Tensor::full(&[mc.depth, mc.heads], 1.0);
    for h in 0..mc.heads {
        partial_mask.set(&[0, h], 0.0); // skip entire block 0
    }
    let partial = session.eval(&state, &x, &y, Some(&partial_mask)).unwrap();
    assert!(
        (full.loss - partial.loss).abs() > 1e-6,
        "skipping a block must change the forward pass"
    );
    println!("fwd-mask eval OK");
}

fn check_score_probe(reg: &ArtifactRegistry) {
    let m = &reg.full_manifest;
    let mc = &m.config;
    let session = Session::new(reg, m).unwrap();
    let store = ParamStore::load(m, reg.dir()).unwrap();
    let state = TrainState::new(&store).unwrap();
    let (xt, yt) = sample_batch(mc, m.micro_batch, 6);
    let probe = session
        .probe_scores(&state, &session.x_literal(&xt).unwrap(), &session.y_literal(&yt).unwrap())
        .unwrap();
    assert_eq!(probe.shape(), &[mc.depth, mc.heads, 4]);
    assert!(probe.data().iter().all(|&v| v >= 0.0), "scores are sums of norms");
    for l in 0..mc.depth {
        for h in 0..mc.heads {
            assert!(probe.at(&[l, h, 3]) > 0.0, "weight magnitude strictly positive");
        }
    }
    println!("score probe OK");
}

fn check_lora(reg: &ArtifactRegistry) {
    if reg.lora_ranks.is_empty() {
        return;
    }
    let rank = reg.lora_standard_rank;
    let m = reg.lora_manifest(rank).unwrap();
    assert_eq!(m.config.lora_rank, rank);
    let session = Session::new(reg, m).unwrap();
    let store = ParamStore::load(m, reg.dir()).unwrap();
    let mut state = TrainState::new(&store).unwrap();
    let (xt, yt) = sample_batch(&m.config, m.micro_batch, 7);
    let x = session.x_literal(&xt).unwrap();
    let y = session.y_literal(&yt).unwrap();
    let ones = MaskPair::ones(m.config.depth, m.config.heads);
    session.step(&mut state, &x, &y, &ones, 0.1).unwrap();
    let mut after = ParamStore::zeros_like(m);
    state.write_back(&mut after).unwrap();
    assert_eq!(
        store.slice("b00_wqkv"),
        after.slice("b00_wqkv"),
        "base weights frozen in LoRA mode"
    );
    assert_ne!(
        store.slice("b00_lora_bq"),
        after.slice("b00_lora_bq"),
        "LoRA B must train"
    );
    println!("lora OK");
}
