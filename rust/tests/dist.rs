//! The `dist` runtime's two contracts, end to end:
//!
//! 1. **Determinism** — `DistTrainer` with K ∈ {1, 2, 4} live worker
//!    replicas produces *bitwise* the same loss trajectory, eval
//!    accuracy, and final parameters as the serial
//!    `coordinator::Trainer` under `UpdateMode::BatchAccum`, in all
//!    four exchange topologies (star allreduce, parameter server, ring,
//!    hierarchical ring). Real threads, real gradient bytes, zero
//!    numeric divergence — with the comm/compute pipeline **on** (the
//!    default) and the parallel matmul kernels engaged (the spec below
//!    sets `threads: 2`), as well as on the serialized `--no-overlap`
//!    path and with workers that receive no tasks at all.
//! 2. **Masked wire format** — encode/decode round-trips the dense
//!    gradient bit-for-bit under random schedules (the freeze contract
//!    makes dropping masked slices lossless), and byte counts shrink
//!    monotonically as heads leave the backward mask.
//!
//! Hermetic: native backend only, no artifacts.
#![cfg(feature = "native")]

use d2ft::backend::native::{NativeBackend, NativeProvider, NativeSpec};
use d2ft::backend::Backend;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::{DatasetSpec, SyntheticKind};
use d2ft::dist::{DistConfig, DistTrainer, ExchangeMode, GradCodec};
use d2ft::runtime::ModelConfig;
use d2ft::schedule::{Budget, MaskPair};
use d2ft::util::proptest::check;

fn small_spec() -> NativeSpec {
    NativeSpec::builder()
        .config(ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        })
        .micro_batch(2)
        .mb_variants(vec![])
        .lora_ranks(vec![2])
        .lora_standard_rank(2)
        .init_seed(0xD157)
        // Acceptance: the bitwise serial ≡ dist contract must hold with
        // the parallel kernels engaged (threads > 1) and overlap on.
        .threads(2)
        .build()
        .expect("small spec")
}

fn cfg(scheduler: SchedulerKind) -> TrainerConfig {
    let mut c =
        TrainerConfig::quick(SyntheticKind::Cifar10Like, scheduler, Budget::uniform(5, 3, 1));
    c.train_size = 120;
    c.test_size = 24;
    c.batches = 3;
    c.pretrain_batches = 1;
    c.update = UpdateMode::BatchAccum;
    c
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn dist_trainer_matches_serial_trainer_bitwise() {
    let provider = NativeProvider::new(small_spec());
    let mut serial = Trainer::new(&provider, cfg(SchedulerKind::D2ft)).unwrap();
    let rs = serial.run().unwrap();
    assert_eq!(rs.loss_curve.len(), 15, "3 batches x 5 micros");
    let serial_w = serial.backend().param("b00_wqkv").unwrap();
    let serial_head = serial.backend().param("z_head_w").unwrap();

    for k in [1usize, 2, 4] {
        let mut dt =
            DistTrainer::new(&provider, DistConfig::new(cfg(SchedulerKind::D2ft), k)).unwrap();
        let rd = dt.run().unwrap();
        assert_eq!(
            bits(&rs.loss_curve),
            bits(&rd.train.loss_curve),
            "K={k}: loss trajectory must be bitwise serial"
        );
        assert_eq!(
            rs.test_top1.to_bits(),
            rd.train.test_top1.to_bits(),
            "K={k}: eval accuracy"
        );
        assert_eq!(
            rs.test_loss.to_bits(),
            rd.train.test_loss.to_bits(),
            "K={k}: eval loss"
        );
        assert_eq!(serial_w, dt.backend().param("b00_wqkv").unwrap(), "K={k}: body weights");
        assert_eq!(serial_head, dt.backend().param("z_head_w").unwrap(), "K={k}: classifier");
        // The exchange is real: bytes moved, and the mask saved some.
        assert!(rd.wire.up_bytes > 0);
        assert!(
            rd.wire.up_bytes < rd.wire.dense_up_bytes,
            "K={k}: masked uplink must be below dense"
        );
        // Scheduler-level accounting matches the serial run exactly.
        assert_eq!(rd.train.compute_fraction.to_bits(), rs.compute_fraction.to_bits());
        assert_eq!(rd.train.workload_variance, 0.0, "D2FT balances exactly");
    }
}

#[test]
fn param_server_matches_allreduce_bitwise() {
    let provider = NativeProvider::new(small_spec());
    let run = |exchange| {
        let dcfg =
            DistConfig::builder(cfg(SchedulerKind::D2ft), 2).exchange(exchange).build().unwrap();
        let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
        let r = dt.run().unwrap();
        (r, dt.backend().param("b01_wo").unwrap())
    };
    let (ra, wa) = run(ExchangeMode::MaskedAllReduce);
    let (rp, wp) = run(ExchangeMode::ParamServer);
    assert_eq!(
        bits(&ra.train.loss_curve),
        bits(&rp.train.loss_curve),
        "exchange topology must not change the numerics"
    );
    assert_eq!(wa, wp, "final params agree across topologies");
    // PS ships dense deltas downlink; masked allreduce ships the union
    // mask, which can never be larger.
    assert!(ra.wire.down_bytes <= rp.wire.down_bytes);
}

#[test]
fn ring_and_hierarchical_match_serial_bitwise() {
    // The chain fold adds the same values in the same ascending
    // micro-batch order as the ordered star reduce, and every replica
    // (aggregator included) applies the exact final bytes that crossed
    // the wire — so both collective topologies must stay bitwise
    // serial, including with more workers than micro-batches (workers
    // holding empty blocks still join the chain).
    let provider = NativeProvider::new(small_spec());
    let mut serial = Trainer::new(&provider, cfg(SchedulerKind::D2ft)).unwrap();
    let rs = serial.run().unwrap();
    let serial_w = serial.backend().param("b00_wqkv").unwrap();
    let serial_head = serial.backend().param("z_head_w").unwrap();
    for exchange in [ExchangeMode::Ring, ExchangeMode::Hierarchical] {
        for k in [1usize, 2, 4, 7] {
            let dcfg = DistConfig::builder(cfg(SchedulerKind::D2ft), k)
                .exchange(exchange)
                .build()
                .unwrap();
            let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
            let rd = dt.run().unwrap();
            assert_eq!(
                bits(&rs.loss_curve),
                bits(&rd.train.loss_curve),
                "{exchange:?} K={k}: loss trajectory must stay bitwise serial"
            );
            assert_eq!(
                rs.test_top1.to_bits(),
                rd.train.test_top1.to_bits(),
                "{exchange:?} K={k}: eval accuracy"
            );
            assert_eq!(
                serial_w,
                dt.backend().param("b00_wqkv").unwrap(),
                "{exchange:?} K={k}: body weights"
            );
            assert_eq!(
                serial_head,
                dt.backend().param("z_head_w").unwrap(),
                "{exchange:?} K={k}: classifier"
            );
            if k > 1 {
                // The partials really rode worker<->worker links.
                let moved: u64 = rd.ring_bytes.iter().map(|&(tx, rx)| tx + rx).sum();
                assert!(moved > 0, "{exchange:?} K={k}: ring links carried no bytes");
            }
        }
    }
}

#[test]
fn serialized_uplink_matches_pipelined_bitwise() {
    // `--no-overlap` (the serialized reference path) and the default
    // pipelined path must produce identical trajectories — overlap only
    // moves *when* bytes travel, never which bytes or their reduction
    // order. Both must equal the serial trainer.
    let provider = NativeProvider::new(small_spec());
    let mut serial = Trainer::new(&provider, cfg(SchedulerKind::D2ft)).unwrap();
    let rs = serial.run().unwrap();
    for overlap in [true, false] {
        let dcfg =
            DistConfig::builder(cfg(SchedulerKind::D2ft), 4).overlap(overlap).build().unwrap();
        let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
        let rd = dt.run().unwrap();
        assert_eq!(
            bits(&rs.loss_curve),
            bits(&rd.train.loss_curve),
            "overlap={overlap}: loss trajectory must stay bitwise serial"
        );
        assert_eq!(
            serial.backend().param("z_head_w").unwrap(),
            dt.backend().param("z_head_w").unwrap(),
            "overlap={overlap}: classifier bits"
        );
    }
}

#[test]
fn param_server_with_idle_worker_stays_bitwise_serial() {
    // 7 workers, 5 micro-batches per batch: at least two workers get no
    // task — a worker that contributes zero trainable slices to every
    // exchange. The barrier must not wait on it, the parameter-server
    // downlink must still reach it, and the trajectory must stay
    // bitwise identical to the serial trainer.
    let provider = NativeProvider::new(small_spec());
    let mut serial = Trainer::new(&provider, cfg(SchedulerKind::D2ft)).unwrap();
    let rs = serial.run().unwrap();
    let dcfg = DistConfig::builder(cfg(SchedulerKind::D2ft), 7)
        .exchange(ExchangeMode::ParamServer)
        .build()
        .unwrap();
    let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
    let rd = dt.run().unwrap();
    assert_eq!(rd.n_workers, 7);
    assert_eq!(bits(&rs.loss_curve), bits(&rd.train.loss_curve));
    assert_eq!(
        serial.backend().param("b00_wqkv").unwrap(),
        dt.backend().param("b00_wqkv").unwrap()
    );
    // The downlink broadcast reaches every worker, busy or idle.
    assert_eq!(rd.wire.down_msgs % 7, 0, "one broadcast per worker per batch");
}

#[test]
fn dist_works_with_lora_and_random_scheduler() {
    // LoRA: only adapters + classifier travel; Random scheduler: no
    // score probes, imbalanced schedules — both must stay serial-exact.
    let provider = NativeProvider::new(small_spec());
    let mut lcfg = cfg(SchedulerKind::Random);
    lcfg.lora_rank = 2;
    let mut serial = Trainer::new(&provider, lcfg.clone()).unwrap();
    let rs = serial.run().unwrap();
    let mut dt = DistTrainer::new(&provider, DistConfig::new(lcfg, 3)).unwrap();
    let rd = dt.run().unwrap();
    assert_eq!(bits(&rs.loss_curve), bits(&rd.train.loss_curve));
    // Frozen base weights never move and never ship.
    assert_eq!(
        serial.backend().param("b00_wqkv").unwrap(),
        dt.backend().param("b00_wqkv").unwrap()
    );
}

#[test]
fn wire_format_round_trip_and_byte_count_property() {
    let spec = small_spec();
    let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 4, 77).generate("train");
    check("masked-grad-wire", 12, |g| {
        let rank = *g.pick(&[0usize, 2]);
        let be = NativeBackend::new(&spec, rank, 2, g.usize_in(0, 1000) as u64);
        let codec = GradCodec::new(&be);
        // Random per-head op assignment: p_f / p_o / p_s.
        let mut masks = MaskPair::ones(2, 2);
        let mut n_pf = 0;
        for l in 0..2 {
            for h in 0..2 {
                match g.usize_in(0, 2) {
                    0 => n_pf += 1, // p_f: fwd 1, bwd 1
                    1 => masks.bwd.set(&[l, h], 0.0), // p_o
                    _ => {
                        masks.fwd.set(&[l, h], 0.0); // p_s
                        masks.bwd.set(&[l, h], 0.0);
                    }
                }
            }
        }
        let (x, y) = data.gather(&[0, 1]);
        let (_, grads) = be.grad_step(&x, &y, &masks).map_err(|e| e.to_string())?;
        let msg = codec.encode(1, &masks, &grads);
        if msg.len() != codec.encoded_len(&masks) {
            return Err("encoded length disagrees with the layout".into());
        }
        // Lossless: decode into zeros reconstructs the dense gradient.
        let mut acc = be.zeros_like_params();
        let micro = codec.decode_add(&msg, &masks, &mut acc).map_err(|e| e.to_string())?;
        if micro != 1 {
            return Err("micro index corrupted".into());
        }
        for (a, grad) in acc.iter().zip(&grads) {
            let (ad, gd) = (a.data(), grad.data());
            if ad.len() != gd.len() {
                return Err("shape mismatch after decode".into());
            }
            for (va, vg) in ad.iter().zip(gd) {
                if va.to_bits() != vg.to_bits() {
                    return Err("decoded gradient is not bitwise equal".into());
                }
            }
        }
        // Byte-count properties: masked <= dense, equality iff all p_f;
        // masking one more head strictly shrinks the message.
        if codec.encoded_len(&masks) > codec.dense_len() {
            return Err("masked message larger than dense".into());
        }
        if n_pf == 4 && codec.encoded_len(&masks) != codec.dense_len() {
            return Err("all-p_f message must be dense".into());
        }
        if n_pf > 0 && rank == 0 {
            // Find an active head and freeze it: bytes must drop.
            let before = codec.encoded_len(&masks);
            'outer: for l in 0..2 {
                for h in 0..2 {
                    if masks.bwd.at(&[l, h]) >= 0.5 {
                        masks.bwd.set(&[l, h], 0.0);
                        break 'outer;
                    }
                }
            }
            if codec.encoded_len(&masks) >= before {
                return Err("freezing a head must shrink the wire".into());
            }
        }
        Ok(())
    });
}
