//! Native-backend semantics: finite-difference gradient checks of the
//! full model, property tests of the MaskPair contract ((a) fully
//! selected == full fine-tuning, (b) p_s == residual identity, (c) p_o
//! participates in the forward but never updates its own weights), and
//! the LoRA rank round-trip. Everything here is hermetic — no artifacts,
//! no native libraries.
#![cfg(feature = "native")]

use d2ft::backend::native::{NativeBackend, NativeProvider, NativeSpec};
use d2ft::backend::{Backend, BackendProvider, BackendSel};
use d2ft::data::{DatasetSpec, SyntheticKind};
use d2ft::runtime::ModelConfig;
use d2ft::schedule::{MaskPair, ScheduleTable};
use d2ft::tensor::Tensor;
use d2ft::util::proptest::check;

/// Small-but-structured spec: 2 blocks x 2 heads, 5 tokens.
fn spec() -> NativeSpec {
    NativeSpec::builder()
        .config(ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        })
        .micro_batch(2)
        .mb_variants(vec![4])
        .lora_ranks(vec![1, 2, 4])
        .lora_standard_rank(2)
        .init_seed(0xD2F7)
        .threads(1)
        .build()
        .expect("test spec")
}

/// Same family at a different depth: parameters shared with `spec()`
/// (embeddings, head, block 0) initialize identically by construction.
fn spec_with_depth(depth: usize) -> NativeSpec {
    let mut s = spec();
    s.config.depth = depth;
    s
}

fn sample(img: usize, mb: usize, seed: u64) -> (Tensor, Vec<i32>) {
    let d = DatasetSpec::preset(SyntheticKind::Cifar10Like, img, mb, seed).generate("train");
    d.gather(&(0..mb).collect::<Vec<_>>())
}

/// The per-head wqkv column slice `(sum of |delta|)` between two
/// parameter snapshots, split into the target head vs all other heads.
fn wqkv_head_delta(
    before: &Tensor,
    after: &Tensor,
    cfg: &ModelConfig,
    head: usize,
) -> (f32, f32) {
    let (d, dh) = (cfg.dim, cfg.head_dim);
    let (mut target, mut others) = (0.0f32, 0.0f32);
    for r in 0..d {
        for p in 0..3 {
            for h in 0..cfg.heads {
                for c in 0..dh {
                    let col = p * d + h * dh + c;
                    let delta =
                        (after.data()[r * 3 * d + col] - before.data()[r * 3 * d + col]).abs();
                    if h == head {
                        target += delta;
                    } else {
                        others += delta;
                    }
                }
            }
        }
    }
    (target, others)
}

// ---------------------------------------------------------------------------
// Gradient correctness
// ---------------------------------------------------------------------------

/// Finite-difference check of the analytic gradients through the whole
/// model: for a handful of parameters, perturb the element with the
/// largest analytic gradient and compare the loss slope.
#[test]
fn native_gradients_match_finite_difference() {
    let s = spec();
    let (x, y) = sample(s.config.img_size, 2, 3);
    let masks = MaskPair::ones(2, 2);
    let mut be = NativeBackend::new(&s, 0, 2, 5);
    let grads = be.param_grads(&x, &y, &masks);
    let eps = 1e-2f32;
    let mut checked = 0;
    for name in [
        "z_head_w", "z_ln_g", "b00_wqkv", "b00_wo", "b00_w1", "b00_b1", "b00_w2",
        "b00_ln1_g", "b01_wqkv", "a_patch_w", "a_pos", "a_cls",
    ] {
        let g = &grads.iter().find(|(n, _)| n == name).unwrap().1;
        // element with the largest analytic gradient
        let (idx, &gv) = g
            .data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .unwrap();
        if gv.abs() < 1e-4 {
            continue; // too flat for a stable f32 finite difference
        }
        be.nudge_param(name, idx, eps);
        let lp = be.eval(&x, &y, None).unwrap().loss;
        be.nudge_param(name, idx, -2.0 * eps);
        let lm = be.eval(&x, &y, None).unwrap().loss;
        be.nudge_param(name, idx, eps); // restore
        let numeric = (lp - lm) / (2.0 * eps);
        let tol = 5e-3 + 5e-2 * gv.abs().max(numeric.abs());
        assert!(
            (gv - numeric).abs() < tol,
            "{name}[{idx}]: analytic {gv} vs finite-difference {numeric}"
        );
        checked += 1;
    }
    assert!(checked >= 8, "only {checked} parameters had usable gradients");
}

// ---------------------------------------------------------------------------
// Mask semantics
// ---------------------------------------------------------------------------

/// (a) A fully-selected MaskPair makes `step` identical to full
/// fine-tuning: stepping with a Standard schedule's masks equals
/// stepping with all-ones masks, bitwise, across random seeds and data.
#[test]
fn full_masks_equal_full_fine_tuning() {
    check("native-full-mask", 8, |g| {
        let s = spec();
        let seed = g.rng().next_u64();
        let (x, y) = sample(s.config.img_size, 2, seed);
        let mut a = NativeBackend::new(&s, 0, 2, seed);
        let mut b = NativeBackend::new(&s, 0, 2, seed);
        let table = ScheduleTable::standard(4, 1);
        let part = d2ft::partition::Partition::per_head(&s.config);
        let table_masks = table.masks_for_micro(&part, 0);
        let ones = MaskPair::ones(2, 2);
        let ra = a.step(&x, &y, &table_masks, 0.05).unwrap();
        let rb = b.step(&x, &y, &ones, 0.05).unwrap();
        if ra.loss != rb.loss {
            return Err(format!("losses diverge: {} vs {}", ra.loss, rb.loss));
        }
        for name in a.param_names() {
            if a.param(&name) != b.param(&name) {
                return Err(format!("param {name} diverges under equivalent masks"));
            }
        }
        Ok(())
    });
}

/// (b) Skipping every head of the deepest block (p_s) leaves that block
/// as the residual identity: the loss equals a model built without the
/// block at all (shared parameters initialize identically by name).
#[test]
fn skipped_block_is_residual_identity() {
    check("native-ps-identity", 6, |g| {
        let seed = g.rng().next_u64();
        let deep = NativeBackend::new(&spec_with_depth(2), 0, 2, seed);
        let shallow = NativeBackend::new(&spec_with_depth(1), 0, 2, seed);
        let (x, y) = sample(8, 2, seed ^ 1);
        // Skip block 1 entirely in the 2-block model.
        let mut mask = Tensor::full(&[2, 2], 1.0);
        mask.set(&[1, 0], 0.0);
        mask.set(&[1, 1], 0.0);
        let masked = deep.eval(&x, &y, Some(&mask)).unwrap();
        let reference = shallow.eval(&x, &y, None).unwrap();
        if (masked.loss - reference.loss).abs() > 1e-6 {
            return Err(format!(
                "p_s block is not the identity: {} vs depth-1 reference {}",
                masked.loss, reference.loss
            ));
        }
        if masked.n_correct != reference.n_correct {
            return Err("prediction sets differ".into());
        }
        Ok(())
    });

    // And the degenerate case: skipping *everything* equals a body-free
    // model (embeddings -> final LN -> head).
    let deep = NativeBackend::new(&spec_with_depth(2), 0, 2, 9);
    let none = NativeBackend::new(&spec_with_depth(0), 0, 2, 9);
    let (x, y) = sample(8, 2, 42);
    let zeros = Tensor::zeros(&[2, 2]);
    let a = deep.eval(&x, &y, Some(&zeros)).unwrap();
    let b = none.eval(&x, &y, None).unwrap();
    assert!(
        (a.loss - b.loss).abs() < 1e-6,
        "all-p_s model must equal the body-free model: {} vs {}",
        a.loss,
        b.loss
    );
}

/// (c) A p_o head (fwd 1, bwd 0) participates in the forward pass —
/// masking it p_s changes the loss — but its own weight slices never
/// move under training, while every other head's do.
#[test]
fn forward_only_head_changes_loss_but_freezes_weights() {
    check("native-po-freeze", 6, |g| {
        let s = spec();
        let seed = g.rng().next_u64();
        let l = g.usize_in(0, 1);
        let h = g.usize_in(0, 1);
        let (x, y) = sample(s.config.img_size, 2, seed ^ 7);
        let mut be = NativeBackend::new(&s, 0, 2, seed);

        // Participates in the forward: p_o loss differs from p_s loss.
        let mut po_fwd = Tensor::full(&[2, 2], 1.0);
        let po = be.eval(&x, &y, None).unwrap();
        po_fwd.set(&[l, h], 0.0);
        let ps = be.eval(&x, &y, Some(&po_fwd)).unwrap();
        if (po.loss - ps.loss).abs() < 1e-7 {
            return Err(format!(
                "skipping head ({l},{h}) should change the forward pass: {} vs {}",
                po.loss, ps.loss
            ));
        }

        // Never updates its own weights: freeze head (l, h).
        let mut masks = MaskPair::ones(2, 2);
        masks.bwd.set(&[l, h], 0.0);
        let before = be.param(&format!("b{l:02}_wqkv")).unwrap();
        be.step(&x, &y, &masks, 0.1).unwrap();
        let after = be.param(&format!("b{l:02}_wqkv")).unwrap();
        let (frozen, others) = wqkv_head_delta(&before, &after, &s.config, h);
        if frozen != 0.0 {
            return Err(format!("p_o head ({l},{h}) moved by {frozen}"));
        }
        if others <= 0.0 {
            return Err("other heads should update".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// LoRA
// ---------------------------------------------------------------------------

/// LoRA rank round-trip: every advertised rank opens a backend whose
/// adapters have the right shapes, train under a step, and leave the
/// base weights untouched; unadvertised ranks are rejected.
#[test]
fn lora_rank_round_trip() {
    let s = spec();
    let provider = NativeProvider::new(s.clone());
    let (x, y) = sample(s.config.img_size, 2, 11);
    let masks = MaskPair::ones(2, 2);
    for rank in provider.spec().lora_ranks.clone() {
        let mut be = provider
            .open(&BackendSel { lora_rank: rank, micro_batch: None, seed: 13 })
            .unwrap();
        assert_eq!(be.config().lora_rank, rank, "rank round-trips through config");
        let cfg = s.config.clone();
        assert_eq!(
            be.param("b00_lora_aq").unwrap().shape(),
            &[cfg.heads, cfg.dim, rank]
        );
        assert_eq!(
            be.param("b00_lora_bq").unwrap().shape(),
            &[cfg.heads, rank, cfg.head_dim]
        );
        let base_before = be.param("b00_wqkv").unwrap();
        let b_before = be.param("b00_lora_bq").unwrap();
        let head_before = be.param("z_head_w").unwrap();
        be.step(&x, &y, &masks, 0.1).unwrap();
        assert_eq!(base_before, be.param("b00_wqkv").unwrap(), "base frozen at rank {rank}");
        assert_ne!(b_before, be.param("b00_lora_bq").unwrap(), "B trains at rank {rank}");
        assert_ne!(head_before, be.param("z_head_w").unwrap(), "head trains at rank {rank}");
    }
    assert!(
        provider
            .open(&BackendSel { lora_rank: 999, micro_batch: None, seed: 13 })
            .is_err(),
        "unadvertised rank rejected"
    );
}

/// The backward mask freezes LoRA adapters per head too.
#[test]
fn lora_adapters_respect_backward_mask() {
    let s = spec();
    let (x, y) = sample(s.config.img_size, 2, 17);
    let mut be = NativeBackend::new(&s, 2, 2, 19);
    let mut masks = MaskPair::ones(2, 2);
    masks.bwd.set(&[0, 1], 0.0); // freeze head 1 of block 0
    let before = be.param("b00_lora_bq").unwrap();
    be.step(&x, &y, &masks, 0.1).unwrap();
    let after = be.param("b00_lora_bq").unwrap();
    let (heads, rank, dh) = (s.config.heads, 2usize, s.config.head_dim);
    assert_eq!(before.shape(), &[heads, rank, dh]);
    let per_head = rank * dh;
    let frozen: f32 = (0..per_head)
        .map(|i| (after.data()[per_head + i] - before.data()[per_head + i]).abs())
        .sum();
    let active: f32 = (0..per_head)
        .map(|i| (after.data()[i] - before.data()[i]).abs())
        .sum();
    assert_eq!(frozen, 0.0, "masked head's adapter must not move");
    assert!(active > 0.0, "unmasked head's adapter must train");
}

// ---------------------------------------------------------------------------
// Score probe
// ---------------------------------------------------------------------------

/// The probe is a pure observation: it matches the gradients the step
/// would apply and leaves no trace on the model.
#[test]
fn score_probe_is_pure_and_grad_consistent() {
    let s = spec();
    let (x, y) = sample(s.config.img_size, 2, 23);
    let be = NativeBackend::new(&s, 0, 2, 29);
    let snapshot: Vec<Tensor> = be.param_names().iter().map(|n| be.param(n).unwrap()).collect();
    let probe = be.score_probe(&x, &y).unwrap();
    assert_eq!(probe.shape(), &[2, 2, 4]);
    for (name, before) in be.param_names().iter().zip(snapshot) {
        assert_eq!(before, be.param(name).unwrap(), "probe mutated {name}");
    }
    // Fisher channel agrees with the sum of squared per-head gradients.
    let grads = be.param_grads(&x, &y, &MaskPair::ones(2, 2));
    let cfg = &s.config;
    let g_wqkv = &grads.iter().find(|(n, _)| n == "b00_wqkv").unwrap().1;
    let mut fisher_wqkv = 0.0f64;
    for r in 0..cfg.dim {
        for p in 0..3 {
            for c in 0..cfg.head_dim {
                let col = p * cfg.dim + c; // head 0 slice
                let g = g_wqkv.data()[r * 3 * cfg.dim + col] as f64;
                fisher_wqkv += g * g;
            }
        }
    }
    // Head (0,0)'s fisher includes wqkv plus wo/FFN slices, so it must
    // be at least the wqkv share and strictly positive.
    assert!(probe.at(&[0, 0, 0]) as f64 >= fisher_wqkv * 0.999);
    assert!(probe.at(&[0, 0, 0]) > 0.0);
}

// ---------------------------------------------------------------------------
// ParamStore interchange (numeric parity harness)
// ---------------------------------------------------------------------------

/// Export -> import round-trips the parameters bitwise, and a backend
/// seeded differently converges to the exporter's exact state after an
/// import — the mechanism that lets both compute backends start from an
/// identical initialization blob.
#[test]
fn param_store_export_import_round_trip() {
    let s = spec();
    let a = NativeBackend::new(&s, 0, 2, 7);
    let mut b = NativeBackend::new(&s, 0, 2, 999);
    assert_ne!(
        a.param("b00_wqkv").unwrap(),
        b.param("b00_wqkv").unwrap(),
        "different seeds must differ before the import"
    );
    let store = a.export_params();
    assert_eq!(store.n_tensors(), a.param_names().len());
    b.import_params(&store).unwrap();
    for name in a.param_names() {
        assert_eq!(a.param(&name).unwrap(), b.param(&name).unwrap(), "param {name}");
    }
    // Identical parameters -> bitwise identical step outcomes.
    let (x, y) = sample(s.config.img_size, 2, 31);
    let masks = MaskPair::ones(2, 2);
    let mut a = a;
    let ra = a.step(&x, &y, &masks, 0.05).unwrap();
    let rb = b.step(&x, &y, &masks, 0.05).unwrap();
    assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());

    // Blob file round trip (the params_init.bin interchange format).
    let dir = std::env::temp_dir().join("d2ft_native_export_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("params_init.bin");
    store.write_blob(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(bytes.len(), store.total_elems() * 4);
}

/// Importing a store with a missing or wrongly-shaped tensor fails
/// loudly instead of silently training from garbage.
#[test]
fn param_store_import_rejects_mismatched_layout() {
    let s = spec();
    let lora = NativeBackend::new(&s, 2, 2, 7);
    let mut full = NativeBackend::new(&s, 0, 2, 7);
    // The rank-0 model has no adapters, but the LoRA export is a
    // superset, so importing it into the full model succeeds...
    full.import_params(&lora.export_params()).unwrap();
    // ...while the reverse is missing the adapter tensors.
    let mut lora = lora;
    assert!(lora.import_params(&full.export_params()).is_err());
}

// ---------------------------------------------------------------------------
// Model presets
// ---------------------------------------------------------------------------

/// The `--model small` preset matches the paper's subnet accounting:
/// 12 blocks x 6 heads = 72 body subnets, 74 devices in total.
#[test]
fn small_preset_matches_paper_subnet_count() {
    let small = NativeSpec::preset("small").unwrap();
    assert_eq!(small.config.depth, 12);
    assert_eq!(small.config.heads, 6);
    assert_eq!(small.config.body_subnets(), 72);
    assert_eq!(small.config.dim, small.config.heads * small.config.head_dim);
    let part = d2ft::partition::Partition::per_head(&small.config);
    assert_eq!(part.n_devices_total(), 74, "the paper's 74-device setting");
    // Parse aliases + rejection.
    assert_eq!(NativeSpec::preset("mini").unwrap().config.depth, 3);
    assert_eq!(NativeSpec::preset("MINI").unwrap().config.depth, 3);
    assert!(NativeSpec::preset("huge").is_err());
    // The preset actually opens (full init) with the advertised shapes.
    let p = NativeProvider::new(small);
    let be = p.open(&BackendSel::full(3)).unwrap();
    assert_eq!(be.config().body_subnets(), 72);
    assert_eq!(be.param("b11_wqkv").unwrap().shape(), &[96, 288]);
}
