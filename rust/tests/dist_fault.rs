//! The elastic control plane's chaos matrix, pinned deterministically.
//!
//! Every scenario scripts its failure through a [`FaultPlan`] (no real
//! machine crashes, no sleeps-as-synchronization in the assertions) and
//! checks the same two things from two angles:
//!
//! 1. **Recovery is invisible to the numerics.** A killed worker's
//!    unfinished micro-batches re-run on survivors, a stalled worker's
//!    are duplicated, a dropped uplink frame is re-requested — and in
//!    every case the loss trajectory and the final parameters are
//!    *bitwise* identical to the fault-free serial reference, because
//!    replicas are bitwise identical and the reduction order is fixed.
//! 2. **The control plane converges.** Evictions, rejoins, membership
//!    events, knapsack re-solves, and checkpoints land exactly where
//!    the scripted plan says they must.
//!
//! Scenarios run over in-process channels and real loopback TCP (the
//! K ∈ {2, 4} × {channel, tcp} matrix), plus one genuine SIGKILL of a
//! forked `repro dist-worker` subprocess. The kill and stall scenarios
//! repeat under the ring/hierarchical exchanges, where recovery
//! additionally tears down and renegotiates the worker↔worker chain.
//! Every run is guarded by an outer timeout — no fault may hang the
//! aggregator.
//!
//! PR 9 extends the matrix to the coordinator itself: an aggregator
//! "crash" mid-epoch (the deterministic `halt_after_batch` simulation —
//! progress record on disk, no shutdown handshake) followed by a
//! `resume_from` directory restart must converge bitwise to the
//! uninterrupted run, for K ∈ {2, 4} over both transports; and the
//! network-layer fault verbs (`reset-after-frame`, `corrupt-frame`,
//! `partition-ms`) must heal via reconnect/NACK-resend with **zero**
//! evictions and zero numeric drift.
#![cfg(feature = "native")]

use std::process::Command;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use d2ft::backend::native::{NativeProvider, NativeSpec};
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::dist::{
    Checkpoint, DistConfig, DistReport, DistTrainer, ExchangeMode, FaultPlan, SpawnMode,
    TransportKind,
};
use d2ft::runtime::ModelConfig;
use d2ft::schedule::Budget;
use d2ft::tensor::Tensor;

fn small_spec() -> NativeSpec {
    NativeSpec::builder()
        .config(ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        })
        .micro_batch(2)
        .mb_variants(vec![])
        .lora_ranks(vec![2])
        .lora_standard_rank(2)
        .init_seed(0xFA17)
        .threads(1)
        .build()
        .expect("small spec")
}

/// `train_size` 40 with micro-batch 2 × 5 micros = exactly 4 batches
/// per epoch, so `batches` 4 is one full epoch and 8 is two — the
/// alignment the checkpoint and rejoin scenarios rely on. No synthetic
/// pretraining: fault plans count gradient sends, and a kill scheduled
/// "after micro 2" should mean fine-tuning micro 2, predictably.
fn fault_cfg(batches: usize) -> TrainerConfig {
    let mut c = TrainerConfig::quick(
        SyntheticKind::Cifar10Like,
        SchedulerKind::D2ft,
        Budget::uniform(5, 3, 1),
    );
    c.train_size = 40;
    c.test_size = 12;
    c.batches = batches;
    c.pretrain_batches = 0;
    c.update = UpdateMode::BatchAccum;
    c
}

/// Chaos-tuned control-plane knobs: fast heartbeats, a liveness window
/// generous enough for loaded CI hosts (1 s = 10 missed beats), a short
/// stall window so straggler duplication actually triggers, and a hard
/// batch deadline far above anything a healthy run needs.
fn chaos(train: TrainerConfig, workers: usize) -> DistConfig {
    let mut dcfg = DistConfig::new(train, workers);
    dcfg.heartbeat_ms = 100;
    dcfg.liveness_misses = 10;
    dcfg.stall_reassign_ms = 300;
    dcfg.batch_timeout_ms = 60_000;
    dcfg
}

fn tcp_threads() -> TransportKind {
    TransportKind::Tcp { listen: "127.0.0.1:0".to_string(), spawn: SpawnMode::Threads }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The fault-free serial reference every recovery run must match
/// bitwise: loss curve plus two parameter tensors.
fn serial_reference(cfg: TrainerConfig) -> (Vec<f32>, Tensor, Tensor) {
    let provider = NativeProvider::new(small_spec());
    let mut t = Trainer::new(&provider, cfg).expect("serial trainer");
    let r = t.run().expect("serial run");
    let w = t.backend().param("b00_wqkv").unwrap();
    let h = t.backend().param("z_head_w").unwrap();
    (r.loss_curve, w, h)
}

type RunOut = anyhow::Result<(DistReport, Tensor, Tensor)>;

/// Run a distributed configuration on its own thread, reporting through
/// a channel — the outer `recv_timeout` is the no-hang guarantee every
/// chaos scenario is required to carry.
fn spawn_run(dcfg: DistConfig) -> mpsc::Receiver<RunOut> {
    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        let provider = NativeProvider::new(small_spec());
        let out = DistTrainer::new(&provider, dcfg).and_then(|mut dt| {
            let r = dt.run()?;
            let w = dt.backend().param("b00_wqkv").unwrap();
            let h = dt.backend().param("z_head_w").unwrap();
            Ok((r, w, h))
        });
        let _ = tx.send(out);
    });
    rx
}

fn wait_run(rx: &mpsc::Receiver<RunOut>, secs: u64) -> (DistReport, Tensor, Tensor) {
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("dist fault run must finish, not hang")
        .expect("dist fault run must succeed")
}

/// Like [`wait_run`] for scenarios that *script a crash*: the run must
/// fail (not hang, not succeed) and the error text comes back for
/// inspection.
fn wait_halt(rx: &mpsc::Receiver<RunOut>, secs: u64) -> String {
    let out = rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("halted dist run must finish, not hang");
    format!("{:#}", out.expect_err("a scripted halt must surface as an error"))
}

/// Reserve a loopback address that is almost certainly free.
fn free_addr() -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    addr
}

#[test]
fn kill_mid_epoch_completes_bitwise_on_survivors() {
    let (curve, sw, sh) = serial_reference(fault_cfg(4));
    for transport in [TransportKind::Channel, tcp_threads()] {
        for k in [2usize, 4] {
            let mut dcfg = chaos(fault_cfg(4), k);
            dcfg.transport = transport.clone();
            dcfg.faults = vec![(0, FaultPlan::parse("kill-after-micro=2").unwrap())];
            let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
            let tag = format!("{} K={k}", r.transport);
            assert_eq!(r.evictions, 1, "{tag}: the killed worker must be evicted");
            assert_eq!(r.joins, 0, "{tag}");
            assert_eq!(r.live_workers, k - 1, "{tag}: survivors finish the run");
            assert!(
                r.reassigned_micros > 0,
                "{tag}: the lost worker's micro-batches must re-run on survivors"
            );
            assert!(r.knapsack_resolves >= 1, "{tag}: eviction must trigger a re-solve");
            assert_eq!(r.membership.len(), 1, "{tag}");
            assert_eq!(r.membership[0].kind, "evict", "{tag}");
            assert_eq!(
                bits(&curve),
                bits(&r.train.loss_curve),
                "{tag}: recovery must not change a single bit of the trajectory"
            );
            assert_eq!(sw, w, "{tag}: body weights bitwise vs serial");
            assert_eq!(sh, h, "{tag}: classifier bitwise vs serial");
        }
    }
}

#[test]
fn ring_kill_mid_epoch_reforms_the_chain_on_survivors() {
    // The collective exchanges must survive the same faults as the
    // star. A worker dying mid-batch surfaces at the metric barrier
    // before any Exec is issued; the attempt restarts on survivors,
    // the chain is renegotiated (fresh nonce, fresh links), and
    // nothing the dead worker partially folded can leak into the
    // update — bitwise vs the fault-free serial reference.
    let (curve, sw, sh) = serial_reference(fault_cfg(4));
    for transport in [TransportKind::Channel, tcp_threads()] {
        for (exchange, k) in [
            (ExchangeMode::Ring, 2usize),
            (ExchangeMode::Ring, 4),
            (ExchangeMode::Hierarchical, 4),
        ] {
            let mut dcfg = chaos(fault_cfg(4), k);
            dcfg.transport = transport.clone();
            dcfg.exchange = exchange;
            dcfg.faults = vec![(0, FaultPlan::parse("kill-after-micro=2").unwrap())];
            let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
            let tag = format!("{} {} K={k}", r.exchange, r.transport);
            assert_eq!(r.evictions, 1, "{tag}: the killed worker must be evicted");
            assert_eq!(r.joins, 0, "{tag}");
            assert_eq!(r.live_workers, k - 1, "{tag}: survivors finish the run");
            assert!(
                r.reassigned_micros > 0,
                "{tag}: the lost worker's block must re-run on survivors"
            );
            assert_eq!(r.membership.len(), 1, "{tag}");
            assert_eq!(r.membership[0].kind, "evict", "{tag}");
            assert_eq!(
                bits(&curve),
                bits(&r.train.loss_curve),
                "{tag}: chain recovery must not change a single bit of the trajectory"
            );
            assert_eq!(sw, w, "{tag}: body weights bitwise vs serial");
            assert_eq!(sh, h, "{tag}: classifier bitwise vs serial");
        }
    }
}

#[test]
fn ring_stall_past_the_window_reassigns_via_eviction() {
    // In the star exchange a stalled micro-batch is duplicated without
    // eviction; a ring attempt cannot carry a silent member (the chain
    // fold would wait on its partial forever), so the stall window
    // evicts it, the attempt restarts on the survivor, and the
    // trajectory still cannot move by a bit.
    let (curve, sw, sh) = serial_reference(fault_cfg(2));
    for transport in [TransportKind::Channel, tcp_threads()] {
        let mut dcfg = chaos(fault_cfg(2), 2);
        dcfg.transport = transport;
        dcfg.exchange = ExchangeMode::Ring;
        dcfg.faults = vec![(1, FaultPlan::parse("stall-ms=1500@1").unwrap())];
        let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
        let tag = format!("ring {}", r.transport);
        assert_eq!(r.evictions, 1, "{tag}: a silent chain member must be evicted");
        assert_eq!(r.live_workers, 1, "{tag}: the survivor finishes the run");
        assert!(r.reassigned_micros > 0, "{tag}: its block must re-run on the survivor");
        assert_eq!(r.membership.len(), 1, "{tag}");
        assert_eq!(r.membership[0].kind, "evict", "{tag}");
        assert_eq!(bits(&curve), bits(&r.train.loss_curve), "{tag}: bitwise vs serial");
        assert_eq!(sw, w, "{tag}: body weights");
        assert_eq!(sh, h, "{tag}: classifier");
    }
}

#[test]
fn stall_is_reassigned_not_evicted() {
    let (curve, sw, sh) = serial_reference(fault_cfg(2));
    for transport in [TransportKind::Channel, tcp_threads()] {
        // 1.5 s stall vs a 300 ms stall window: the barrier must
        // duplicate the stalled micro long before the slow copy lands,
        // while the heartbeat thread keeps the liveness detector quiet.
        let mut dcfg = chaos(fault_cfg(2), 2);
        dcfg.transport = transport;
        dcfg.faults = vec![(1, FaultPlan::parse("stall-ms=1500@1").unwrap())];
        let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
        let tag = &r.transport;
        assert_eq!(r.evictions, 0, "{tag}: slow-but-alive must not be evicted");
        assert_eq!(r.live_workers, 2, "{tag}");
        assert!(r.reassigned_micros > 0, "{tag}: stalled micros must be duplicated");
        assert!(r.membership.is_empty(), "{tag}: no membership churn on a stall");
        assert_eq!(bits(&curve), bits(&r.train.loss_curve), "{tag}: bitwise vs serial");
        assert_eq!(sw, w, "{tag}: body weights");
        assert_eq!(sh, h, "{tag}: classifier");
    }
}

#[test]
fn dropped_uplink_frame_is_recovered_without_eviction() {
    let (curve, sw, sh) = serial_reference(fault_cfg(2));
    for transport in [TransportKind::Channel, tcp_threads()] {
        let mut dcfg = chaos(fault_cfg(2), 2);
        dcfg.transport = transport;
        dcfg.faults = vec![(0, FaultPlan::parse("drop-uplink=1").unwrap())];
        let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
        let tag = &r.transport;
        assert_eq!(r.evictions, 0, "{tag}: a lost frame is not a lost worker");
        assert!(r.reassigned_micros > 0, "{tag}: the dropped micro must be re-run");
        assert_eq!(bits(&curve), bits(&r.train.loss_curve), "{tag}: bitwise vs serial");
        assert_eq!(sw, w, "{tag}: body weights");
        assert_eq!(sh, h, "{tag}: classifier");
    }
}

#[test]
fn kill_then_rejoin_converges_with_fresh_state() {
    let (curve, sw, sh) = serial_reference(fault_cfg(8));
    for transport in [TransportKind::Channel, tcp_threads()] {
        // Worker 0 dies during epoch 1 and is respawned at the epoch
        // boundary. The rejoiner's deterministic init is epochs stale,
        // so the bitwise assertion below doubles as proof that the
        // State transfer (params + momentum) actually installed.
        let plan = FaultPlan::parse("kill-after-micro=2;rejoin-at-epoch=1").unwrap();
        let mut dcfg = chaos(fault_cfg(8), 2);
        dcfg.transport = transport.clone();
        dcfg.faults = vec![(0, plan)];
        let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
        let tag = format!("{}", r.transport);
        assert_eq!(r.evictions, 1, "{tag}");
        assert_eq!(r.joins, 1, "{tag}: the scripted rejoin must happen");
        assert_eq!(r.live_workers, 2, "{tag}: membership must converge back to full");
        assert!(
            r.knapsack_resolves >= 2,
            "{tag}: evict and rejoin must each trigger a re-solve, got {}",
            r.knapsack_resolves
        );
        assert_eq!(r.membership.len(), 2, "{tag}");
        assert_eq!(r.membership[0].kind, "evict", "{tag}");
        assert_eq!(r.membership[1].kind, "join", "{tag}");
        assert_eq!(bits(&curve), bits(&r.train.loss_curve), "{tag}: bitwise vs serial");
        assert_eq!(sw, w, "{tag}: body weights");
        assert_eq!(sh, h, "{tag}: classifier");
    }
}

#[test]
fn checkpoint_resume_matches_the_uninterrupted_run_bitwise() {
    let dir = std::env::temp_dir().join(format!("d2ft-fault-ckpt-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // Run A: 8 batches (two epochs) straight through.
    let (ra, wa, ha) = wait_run(&spawn_run(chaos(fault_cfg(8), 2)), 180);
    assert_eq!(ra.epochs, 2);

    // Run B1: the same run stopped after epoch 1, checkpointing.
    let mut dcfg = chaos(fault_cfg(4), 2);
    dcfg.checkpoint_dir = Some(dir.clone());
    let (rb1, _, _) = wait_run(&spawn_run(dcfg), 180);
    assert_eq!(rb1.checkpoints_written, 1, "one epoch boundary, one checkpoint");
    let ckpt = dir.join("ckpt_e1.d2ck");
    assert!(ckpt.exists(), "checkpoint file must land at {}", ckpt.display());

    // Run B2: resume from the checkpoint and finish epoch 2. The
    // resumed tail must equal run A's tail bitwise — losses and params.
    let mut dcfg = chaos(fault_cfg(8), 2);
    dcfg.resume_from = Some(ckpt.clone());
    let (rb2, wb, hb) = wait_run(&spawn_run(dcfg), 180);
    assert_eq!(rb2.train.batches, 8, "resume must continue to the configured end");
    let half = ra.train.loss_curve.len() / 2;
    assert_eq!(
        bits(&ra.train.loss_curve[half..]),
        bits(&rb2.train.loss_curve),
        "the resumed epoch must replay the uninterrupted run bitwise"
    );
    assert_eq!(wa, wb, "resumed body weights");
    assert_eq!(ha, hb, "resumed classifier");

    // A corrupt checkpoint must be rejected descriptively, not loaded.
    let mut bytes = std::fs::read(&ckpt).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&ckpt, &bytes).unwrap();
    let err = format!("{:#}", Checkpoint::load(&ckpt).unwrap_err());
    assert!(err.contains("checksum mismatch"), "got: {err}");
    // ...and a truncated one too.
    let good_len = bytes.len();
    std::fs::write(&ckpt, &bytes[..good_len - 9]).unwrap();
    let err = format!("{:#}", Checkpoint::load(&ckpt).unwrap_err());
    assert!(err.contains("checksum mismatch"), "got: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sigkill_subprocess_worker_is_evicted_and_the_run_completes() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let (curve, sw, sh) = serial_reference(fault_cfg(8));
    let addr = free_addr();
    let mut dcfg = chaos(fault_cfg(8), 4);
    dcfg.transport = TransportKind::Tcp { listen: addr.clone(), spawn: SpawnMode::External };
    let rx = spawn_run(dcfg);
    // Three honest workers plus one victim, all real `repro
    // dist-worker` subprocesses over real sockets. The victim's
    // scripted 20 s stall guarantees the run is still in flight when
    // the SIGKILL lands (each stalled batch waits out the 300 ms
    // duplication window, so 8 batches cannot finish in 1.5 s).
    let mut honest = Vec::new();
    for _ in 0..3 {
        let child = Command::new(exe)
            .args(["dist-worker", "--connect", addr.as_str(), "--quiet"])
            .spawn()
            .expect("spawning honest dist-worker");
        honest.push(child);
    }
    let mut victim = Command::new(exe)
        .args(["dist-worker", "--connect", addr.as_str(), "--quiet", "--fault", "stall-ms=20000@2"])
        .spawn()
        .expect("spawning victim dist-worker");
    thread::sleep(Duration::from_millis(1500));
    victim.kill().expect("SIGKILLing the victim");
    victim.wait().expect("reaping the victim");

    let (r, w, h) = wait_run(&rx, 180);
    assert_eq!(r.evictions, 1, "the SIGKILLed subprocess must be evicted");
    assert_eq!(r.live_workers, 3, "the three honest subprocesses survive");
    assert!(r.reassigned_micros > 0, "its work must move to survivors");
    assert_eq!(
        bits(&curve),
        bits(&r.train.loss_curve),
        "a SIGKILL mid-run must not change a single bit of the trajectory"
    );
    assert_eq!(sw, w, "body weights bitwise vs serial");
    assert_eq!(sh, h, "classifier bitwise vs serial");
    for mut child in honest {
        child.wait().expect("reaping honest dist-worker");
    }
}

#[test]
fn aggregator_crash_and_resume_matches_the_uninterrupted_run_bitwise() {
    // The coordinator dies mid-epoch-2 (the deterministic
    // `halt_after_batch` crash simulation: the batch-5 progress record
    // is on disk, no shutdown handshake ran) and a fresh aggregator
    // restarts from the checkpoint *directory* — newest loadable epoch
    // checkpoint plus the progress record's restart counter. The
    // resumed tail must replay the fault-free serial reference
    // bitwise, params included, for K ∈ {2, 4} over both transports.
    let (curve, sw, sh) = serial_reference(fault_cfg(8));
    for (label, transport) in [("chan", TransportKind::Channel), ("tcp", tcp_threads())] {
        for k in [2usize, 4] {
            let dir = std::env::temp_dir()
                .join(format!("d2ft-agg-crash-{}-{label}-{k}", std::process::id()));
            std::fs::remove_dir_all(&dir).ok();
            let tag = format!("{label} K={k}");

            let mut dcfg = chaos(fault_cfg(8), k);
            dcfg.transport = transport.clone();
            dcfg.checkpoint_dir = Some(dir.clone());
            dcfg.halt_after_batch = Some(5);
            let err = wait_halt(&spawn_run(dcfg), 180);
            assert!(err.contains("halted after batch 5"), "{tag}: got: {err}");
            assert!(
                dir.join("ckpt_e1.d2ck").exists(),
                "{tag}: the epoch-1 checkpoint must have survived the crash"
            );
            assert!(
                dir.join("progress.d2pr").exists(),
                "{tag}: the progress record must have survived the crash"
            );

            let mut dcfg = chaos(fault_cfg(8), k);
            dcfg.transport = transport.clone();
            dcfg.checkpoint_dir = Some(dir.clone());
            dcfg.resume_from = Some(dir.clone());
            let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
            assert_eq!(
                r.aggregator_restarts, 1,
                "{tag}: the restart generation must come from the progress record"
            );
            assert_eq!(r.epochs, 2, "{tag}: resume must finish the configured run");
            assert_eq!(
                bits(&curve[4..]),
                bits(&r.train.loss_curve),
                "{tag}: the resumed tail must replay the uninterrupted run bitwise"
            );
            assert_eq!(sw, w, "{tag}: body weights bitwise vs serial");
            assert_eq!(sh, h, "{tag}: classifier bitwise vs serial");
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn checkpoint_rotation_keeps_only_the_retained_tail() {
    // Four epochs with `checkpoint_retain = 2`: only the two newest
    // epoch checkpoints may remain on disk, and the survivors must
    // still be loadable (rotation deletes, never touches the keepers).
    let dir = std::env::temp_dir().join(format!("d2ft-fault-rotate-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut dcfg = chaos(fault_cfg(16), 2);
    dcfg.checkpoint_dir = Some(dir.clone());
    dcfg.checkpoint_retain = 2;
    let (r, _, _) = wait_run(&spawn_run(dcfg), 180);
    assert_eq!(r.epochs, 4);
    assert_eq!(r.checkpoints_written, 4, "every epoch boundary checkpoints");
    for (epoch, expect) in [(1, false), (2, false), (3, true), (4, true)] {
        let p = dir.join(format!("ckpt_e{epoch}.d2ck"));
        assert_eq!(p.exists(), expect, "rotation with retain=2: {}", p.display());
    }
    Checkpoint::load(&dir.join("ckpt_e4.d2ck")).expect("retained checkpoint must load");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn transient_reset_reconnects_without_eviction() {
    // Worker 1's link is scripted to die once mid-run — a connection
    // reset, not a process crash. The surviving worker process redials
    // with backoff inside the aggregator's accept window and re-Joins
    // under its learned identity: a reconnect, not an eviction, and
    // not a bit of numeric drift.
    let (curve, sw, sh) = serial_reference(fault_cfg(4));
    let mut dcfg = chaos(fault_cfg(4), 2);
    dcfg.transport = tcp_threads();
    dcfg.faults = vec![(1, FaultPlan::parse("reset-after-frame=6").unwrap())];
    let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
    assert_eq!(r.evictions, 0, "a transient reset must heal, not evict");
    assert!(r.reconnects >= 1, "the redial must be counted, got {}", r.reconnects);
    assert_eq!(r.live_workers, 2, "membership must converge back to full");
    assert!(
        r.membership.iter().any(|e| e.kind == "reconnect"),
        "membership log must record the reconnect, got kinds {:?}",
        r.membership.iter().map(|e| e.kind.as_str()).collect::<Vec<_>>()
    );
    assert_eq!(bits(&curve), bits(&r.train.loss_curve), "bitwise vs serial");
    assert_eq!(sw, w, "body weights");
    assert_eq!(sh, h, "classifier");
}

#[test]
fn corrupt_frame_is_nacked_and_resent_not_evicted() {
    // Worker 1's 7th outbound frame is delivered with a damaged CRC32C
    // trailer. The aggregator must detect it, answer with a NACK (the
    // worker resends its retained frame; the stall window backstops the
    // case where the damaged frame was not the retained one), and the
    // run must finish with zero evictions and zero numeric drift —
    // over both the channel and TCP framing.
    let (curve, sw, sh) = serial_reference(fault_cfg(4));
    for transport in [TransportKind::Channel, tcp_threads()] {
        let mut dcfg = chaos(fault_cfg(4), 2);
        dcfg.transport = transport;
        dcfg.faults = vec![(1, FaultPlan::parse("corrupt-frame=7").unwrap())];
        let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
        let tag = &r.transport;
        assert_eq!(r.evictions, 0, "{tag}: corruption is retryable, never an eviction");
        assert!(r.frames_corrupt >= 1, "{tag}: the damaged trailer must be detected");
        assert!(r.resends >= 1, "{tag}: the corrupt arrival must be NACKed for a resend");
        assert_eq!(r.live_workers, 2, "{tag}");
        assert_eq!(bits(&curve), bits(&r.train.loss_curve), "{tag}: bitwise vs serial");
        assert_eq!(sw, w, "{tag}: body weights");
        assert_eq!(sh, h, "{tag}: classifier");
    }
}

#[test]
fn partition_then_heal_converges_membership_without_eviction() {
    // From its 6th outbound frame, worker 1's link fails in both
    // directions for 300 ms, then heals — shorter than the
    // aggregator's 1 s accept window, so the post-heal redial must
    // land as a reconnect while the failed mid-partition dial attempts
    // are consumed and discarded by the accept loop.
    let (curve, sw, sh) = serial_reference(fault_cfg(4));
    let mut dcfg = chaos(fault_cfg(4), 2);
    dcfg.transport = tcp_threads();
    dcfg.faults = vec![(1, FaultPlan::parse("partition-ms=300@6").unwrap())];
    let (r, w, h) = wait_run(&spawn_run(dcfg), 180);
    assert_eq!(r.evictions, 0, "a healed partition must not cost the worker its seat");
    assert!(r.reconnects >= 1, "got {} reconnects", r.reconnects);
    assert_eq!(r.live_workers, 2, "membership must converge back to full");
    assert!(
        r.membership.iter().any(|e| e.kind == "reconnect"),
        "membership log must record the reconnect, got kinds {:?}",
        r.membership.iter().map(|e| e.kind.as_str()).collect::<Vec<_>>()
    );
    assert_eq!(bits(&curve), bits(&r.train.loss_curve), "bitwise vs serial");
    assert_eq!(sw, w, "body weights");
    assert_eq!(sh, h, "classifier");
}
