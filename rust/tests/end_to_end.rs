//! End-to-end coordinator tests on the native backend: short D2FT runs
//! must train, balance workloads, and respect budgets — hermetically, on
//! every machine (no artifacts, no native libraries).
//!
//! The same scenarios run against the XLA backend in CI's `xla` job via
//! `tests/backend_parity.rs`.
#![cfg(feature = "native")]

use d2ft::backend::native::NativeProvider;
use d2ft::backend::Backend;
use d2ft::cluster::HeteroSpec;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig};
use d2ft::data::SyntheticKind;
use d2ft::schedule::Budget;

fn short_cfg(scheduler: SchedulerKind, budget: Budget) -> TrainerConfig {
    TrainerConfig::builder()
        .dataset(SyntheticKind::Cifar10Like)
        .scheduler(scheduler)
        .budget(budget)
        .train_size(160)
        .test_size(32)
        .batches(3)
        .pretrain_batches(1)
        .build()
        .expect("short config")
}

#[test]
fn coordinator_suite() {
    let provider = NativeProvider::default();

    // --- D2FT short run: trains, balances, budgets exact ----------------
    let cfg = short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 3, 1));
    let mut t = Trainer::new(&provider, cfg).unwrap();
    let r = t.run().unwrap();
    assert_eq!(r.batches, 3);
    assert_eq!(r.loss_curve.len(), 15, "5 micro-steps per batch");
    assert!(r.final_train_loss.is_finite() && r.final_train_loss > 0.0);
    assert_eq!(r.workload_variance, 0.0, "D2FT must balance exactly");
    assert!((r.compute_fraction - 0.68).abs() < 1e-9);
    assert!((r.comm_fraction - 0.70).abs() < 1e-9);
    assert!(r.test_top1 >= 0.0 && r.test_top1 <= 1.0);
    assert_eq!(r.backend, "native");
    println!("d2ft short run OK");

    // --- model learns on easy data over a slightly longer run ------------
    let cfg = TrainerConfig::builder()
        .dataset(SyntheticKind::Cifar10Like)
        .scheduler(SchedulerKind::D2ft)
        .budget(Budget::uniform(5, 3, 1))
        .batches(14)
        .pretrain_batches(8)
        .train_size(240)
        .test_size(40)
        .lr(0.05)
        .build()
        .expect("learning config");
    let mut t = Trainer::new(&provider, cfg).unwrap();
    let r = t.run().unwrap();
    // 10-way task on a 196-logit head: chance is far below 12%.
    assert!(
        r.test_top1 > 0.12,
        "D2FT should be well above chance after 14 batches: top-1 {}",
        r.test_top1
    );
    // The loss curve itself must trend down over the run.
    let early: f32 = r.loss_curve[..5].iter().sum::<f32>() / 5.0;
    let late: f32 = r.loss_curve[r.loss_curve.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        late < early,
        "training loss should fall: first-5 mean {early} vs last-5 mean {late}"
    );
    println!("learns OK (top-1 {:.1}%)", r.test_top1 * 100.0);

    // --- Random baseline runs but cannot balance -------------------------
    let cfg = short_cfg(SchedulerKind::Random, Budget::uniform(5, 3, 0));
    let mut t = Trainer::new(&provider, cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.workload_variance > 0.0, "random cannot balance");
    println!("random baseline OK");

    // --- heterogeneity: merged partition trains --------------------------
    let body = provider.spec().config.body_subnets();
    let mut cfg = short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 2, 2));
    cfg.hetero = Some(HeteroSpec::memory(5));
    let mut t = Trainer::new(&provider, cfg).unwrap();
    assert_eq!(t.partition().n_subnets(), body - 5);
    let r = t.run().unwrap();
    assert!(r.final_train_loss.is_finite());
    println!("hetero OK");

    // --- partition granularity wiring ------------------------------------
    let mut cfg = short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 2, 2));
    cfg.partition_group = 2;
    let t = Trainer::new(&provider, cfg).unwrap();
    assert_eq!(t.partition().n_subnets(), body / 2);
    println!("partition-group OK");

    // --- micro-batch variant (Table VI wiring) ---------------------------
    let mut cfg = short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 3, 1));
    cfg.micro_batch = Some(2);
    let mut t = Trainer::new(&provider, cfg).unwrap();
    assert_eq!(t.backend().micro_batch(), 2);
    let r = t.run().unwrap();
    assert!(r.final_train_loss.is_finite());
    println!("mb-variant OK");

    // --- LoRA run: adapters train, base weights frozen --------------------
    let rank = provider.spec().lora_standard_rank;
    let mut cfg = short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 3, 1));
    cfg.lora_rank = rank;
    let mut t = Trainer::new(&provider, cfg).unwrap();
    let base_before = t.backend().param("b00_wqkv").unwrap();
    let adapter_before = t.backend().param("b00_lora_bq").unwrap();
    let r = t.run().unwrap();
    assert!(r.final_train_loss.is_finite());
    assert_eq!(
        base_before,
        t.backend().param("b00_wqkv").unwrap(),
        "base weights frozen in LoRA mode"
    );
    assert_ne!(
        adapter_before,
        t.backend().param("b00_lora_bq").unwrap(),
        "LoRA B must train"
    );
    println!("lora OK");
}
