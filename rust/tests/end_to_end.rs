//! End-to-end coordinator tests on the real artifacts: short D2FT runs
//! must train, balance workloads, and respect budgets.
//!
//! All scenarios share ONE #[test] (and one registry) so XLA compilation
//! happens once per binary. Skips when artifacts are absent.

use d2ft::cluster::HeteroSpec;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig};
use d2ft::data::SyntheticKind;
use d2ft::runtime::ArtifactRegistry;
use d2ft::schedule::Budget;

fn short_cfg(scheduler: SchedulerKind, budget: Budget) -> TrainerConfig {
    TrainerConfig {
        train_size: 160,
        test_size: 32,
        batches: 3,
        pretrain_batches: 1,
        ..TrainerConfig::quick(SyntheticKind::Cifar10Like, scheduler, budget)
    }
}

#[test]
fn coordinator_suite() {
    let Ok(reg) = ArtifactRegistry::open_default() else {
        eprintln!("skipping e2e tests (run `make artifacts`)");
        return;
    };

    // --- D2FT short run: trains, balances, budgets exact ----------------
    let cfg = short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 3, 1));
    let mut t = Trainer::new(&reg, &reg.full_manifest, cfg).unwrap();
    let r = t.run().unwrap();
    assert_eq!(r.batches, 3);
    assert_eq!(r.loss_curve.len(), 15, "5 micro-steps per batch");
    assert!(r.final_train_loss.is_finite() && r.final_train_loss > 0.0);
    assert_eq!(r.workload_variance, 0.0, "D2FT must balance exactly");
    assert!((r.compute_fraction - 0.68).abs() < 1e-9);
    assert!((r.comm_fraction - 0.70).abs() < 1e-9);
    assert!(r.test_top1 >= 0.0 && r.test_top1 <= 1.0);
    println!("d2ft short run OK");

    // --- model learns on easy data over a slightly longer run ------------
    let cfg = TrainerConfig {
        batches: 10,
        pretrain_batches: 8,
        train_size: 240,
        test_size: 40,
        lr: 0.03,
        ..TrainerConfig::quick(
            SyntheticKind::Cifar10Like,
            SchedulerKind::D2ft,
            Budget::uniform(5, 3, 1),
        )
    };
    let mut t = Trainer::new(&reg, &reg.full_manifest, cfg).unwrap();
    let r = t.run().unwrap();
    // 10-way task on a 196-logit head: chance is far below 12%.
    assert!(
        r.test_top1 > 0.12,
        "D2FT should be well above chance after 8 batches: top-1 {}",
        r.test_top1
    );
    println!("learns OK (top-1 {:.1}%)", r.test_top1 * 100.0);

    // --- Random baseline runs but cannot balance -------------------------
    let cfg = short_cfg(SchedulerKind::Random, Budget::uniform(5, 3, 0));
    let mut t = Trainer::new(&reg, &reg.full_manifest, cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.workload_variance > 0.0, "random cannot balance");
    println!("random baseline OK");

    // --- heterogeneity: merged partition trains --------------------------
    let cfg = TrainerConfig {
        hetero: Some(HeteroSpec::memory(5)),
        ..short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 2, 2))
    };
    let mut t = Trainer::new(&reg, &reg.full_manifest, cfg).unwrap();
    assert_eq!(t.partition().n_subnets(), reg.full_manifest.config.body_subnets() - 5);
    let r = t.run().unwrap();
    assert!(r.final_train_loss.is_finite());
    println!("hetero OK");

    // --- partition granularity wiring ------------------------------------
    let cfg = TrainerConfig {
        partition_group: 2,
        ..short_cfg(SchedulerKind::D2ft, Budget::uniform(5, 2, 2))
    };
    let t = Trainer::new(&reg, &reg.full_manifest, cfg).unwrap();
    assert_eq!(t.partition().n_subnets(), reg.full_manifest.config.body_subnets() / 2);
    println!("partition-group OK");
}
