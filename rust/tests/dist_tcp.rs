//! The transport seam's contract, end to end:
//!
//! 1. **Cross-transport determinism** — the same distributed run over
//!    in-process channels and over real TCP loopback sockets produces
//!    *bitwise* identical trajectories, eval metrics, and parameters,
//!    and (on the lossless f32 wire) both equal the serial
//!    `coordinator::Trainer` under `UpdateMode::BatchAccum` — for
//!    K ∈ {2, 4}, comm/compute overlap on and off, and both wire
//!    precisions. The TCP workers run the *same* `run_worker` loop a
//!    `repro dist-worker` subprocess runs; only the socket is local.
//! 2. **Failure modes** — a worker that drops its connection mid-epoch
//!    is evicted and its work re-runs on the survivor (bitwise equal to
//!    the serial reference, never a hung barrier); a malformed uplink
//!    frame, a gradient tail whose compression flags disagree with the
//!    codec, a garbled Join, or a protocol-version mismatch is rejected
//!    with a descriptive error rather than a panic or a misparse.
//!
//! Hermetic: native backend only, loopback sockets only.
#![cfg(feature = "native")]

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use d2ft::backend::native::{NativeProvider, NativeSpec};
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::dist::{
    run_worker, BlobRx, BlobTx, BufPool, DistConfig, DistReport, DistTrainer, SpawnMode,
    TcpTransport, Transport, TransportKind, WireCompression, WirePrecision,
};
use d2ft::runtime::ModelConfig;
use d2ft::schedule::Budget;
use d2ft::tensor::Tensor;

fn small_spec() -> NativeSpec {
    NativeSpec::builder()
        .config(ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        })
        .micro_batch(2)
        .mb_variants(vec![])
        .lora_ranks(vec![2])
        .lora_standard_rank(2)
        .init_seed(0x7C9)
        .threads(1)
        .build()
        .expect("small spec")
}

fn cfg() -> TrainerConfig {
    let mut c = TrainerConfig::quick(
        SyntheticKind::Cifar10Like,
        SchedulerKind::D2ft,
        Budget::uniform(5, 3, 1),
    );
    c.train_size = 80;
    c.test_size = 16;
    c.batches = 2;
    c.pretrain_batches = 1;
    c.update = UpdateMode::BatchAccum;
    c
}

/// Loopback TCP with in-process worker threads: every socket byte is
/// real, no subprocess needed.
fn tcp_threads() -> TransportKind {
    TransportKind::Tcp { listen: "127.0.0.1:0".to_string(), spawn: SpawnMode::Threads }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Run one distributed configuration and return the report plus two
/// parameter tensors (body weights + classifier) for bitwise checks.
fn run_dist(
    provider: &NativeProvider,
    transport: TransportKind,
    workers: usize,
    overlap: bool,
    wire: WirePrecision,
) -> (DistReport, Tensor, Tensor) {
    let dcfg = DistConfig::builder(cfg(), workers)
        .transport(transport)
        .overlap(overlap)
        .wire_precision(wire)
        .build()
        .expect("dist config");
    let mut dt = DistTrainer::new(provider, dcfg).expect("building dist trainer");
    let r = dt.run().expect("dist run");
    let w = dt.backend().param("b00_wqkv").unwrap();
    let head = dt.backend().param("z_head_w").unwrap();
    (r, w, head)
}

#[test]
fn tcp_matches_channel_and_serial_bitwise_f32() {
    let provider = NativeProvider::new(small_spec());
    let mut serial = Trainer::new(&provider, cfg()).unwrap();
    let rs = serial.run().unwrap();
    let serial_w = serial.backend().param("b00_wqkv").unwrap();
    let serial_head = serial.backend().param("z_head_w").unwrap();
    for k in [2usize, 4] {
        for overlap in [true, false] {
            let (rc, wc, hc) = run_dist(
                &provider,
                TransportKind::Channel,
                k,
                overlap,
                WirePrecision::F32,
            );
            let (rt, wt, ht) =
                run_dist(&provider, tcp_threads(), k, overlap, WirePrecision::F32);
            let tag = format!("K={k} overlap={overlap}");
            assert_eq!(rt.transport, "tcp", "{tag}");
            assert_eq!(rc.transport, "channel", "{tag}");
            assert_eq!(
                bits(&rs.loss_curve),
                bits(&rc.train.loss_curve),
                "{tag}: channel loss trajectory must be bitwise serial"
            );
            assert_eq!(
                bits(&rs.loss_curve),
                bits(&rt.train.loss_curve),
                "{tag}: tcp loss trajectory must be bitwise serial"
            );
            assert_eq!(
                rs.test_top1.to_bits(),
                rt.train.test_top1.to_bits(),
                "{tag}: tcp eval accuracy"
            );
            assert_eq!(serial_w, wc, "{tag}: channel body weights");
            assert_eq!(serial_w, wt, "{tag}: tcp body weights");
            assert_eq!(serial_head, hc, "{tag}: channel classifier");
            assert_eq!(serial_head, ht, "{tag}: tcp classifier");
            // The gradient byte accounting is transport-independent...
            assert_eq!(rc.wire.up_bytes, rt.wire.up_bytes, "{tag}: same wire bytes");
            assert_eq!(rc.grad_savings, rt.grad_savings, "{tag}: same savings");
            // ...while the socket totals cover it plus framing/control.
            assert!(
                rt.socket.bytes_recv >= rt.wire.up_bytes + rt.pretrain_wire.up_bytes,
                "{tag}: socket recv must cover every gradient byte"
            );
            assert!(rt.socket.bytes_sent > 0, "{tag}: init/jobs/broadcasts crossed the socket");
        }
    }
}

#[test]
fn tcp_matches_channel_bitwise_f16() {
    // The f16 wire is lossy vs the serial trainer by design, but the
    // requantized trajectory must still be bitwise identical across
    // transports — same bytes, same reduction, different pipes.
    let provider = NativeProvider::new(small_spec());
    for k in [2usize, 4] {
        for overlap in [true, false] {
            let (rc, wc, hc) = run_dist(
                &provider,
                TransportKind::Channel,
                k,
                overlap,
                WirePrecision::F16,
            );
            let (rt, wt, ht) =
                run_dist(&provider, tcp_threads(), k, overlap, WirePrecision::F16);
            let tag = format!("K={k} overlap={overlap}");
            assert_eq!(
                bits(&rc.train.loss_curve),
                bits(&rt.train.loss_curve),
                "{tag}: f16 trajectories must agree across transports"
            );
            assert_eq!(wc, wt, "{tag}: f16 body weights");
            assert_eq!(hc, ht, "{tag}: f16 classifier");
            assert_eq!(rc.wire.up_bytes, rt.wire.up_bytes, "{tag}: same f16 bytes");
        }
    }
}

/// Reserve a loopback address that is almost certainly free: bind an
/// ephemeral port, note it, release it.
fn free_addr() -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    addr
}

/// Launch a trainer over external-worker TCP in a thread, reporting
/// its run() result through a channel (so a hang fails the test by
/// timeout instead of blocking forever).
fn spawn_trainer(
    addr: String,
    workers: usize,
    compress: WireCompression,
) -> mpsc::Receiver<anyhow::Result<DistReport>> {
    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        let provider = NativeProvider::new(small_spec());
        let dcfg = DistConfig::builder(cfg(), workers)
            .transport(TransportKind::Tcp { listen: addr, spawn: SpawnMode::External })
            .compress(compress)
            .build()
            .expect("dist config");
        let result = DistTrainer::new(&provider, dcfg).and_then(|mut dt| dt.run());
        let _ = tx.send(result);
    });
    rx
}

/// Connect a mock worker and send the `Join` half of the handshake —
/// the control plane refuses links that never identify themselves.
fn connect_and_join(addr: &str) -> TcpTransport {
    let pool = Arc::new(BufPool::new());
    let mut t =
        TcpTransport::connect(addr, Duration::from_secs(10), pool).expect("mock worker connect");
    let mut join = Vec::new();
    d2ft::dist::proto::encode_join(
        &d2ft::dist::proto::JoinMsg::fresh(d2ft::dist::proto::PROTO_VERSION),
        &mut join,
    );
    t.send_blob(join).expect("sending Join");
    t
}

#[test]
fn worker_disconnect_mid_epoch_recovers_on_the_survivor() {
    // Serial reference first: recovery must be numerically invisible.
    let provider = NativeProvider::new(small_spec());
    let mut serial = Trainer::new(&provider, cfg()).unwrap();
    let rs = serial.run().unwrap();
    let addr = free_addr();
    let result_rx = spawn_trainer(addr.clone(), 2, WireCompression::None);
    // One honest worker: the real run_worker loop over a real socket.
    // It must finish cleanly — its sibling's death is not its problem.
    let honest_addr = addr.clone();
    let honest = thread::spawn(move || {
        let pool = Arc::new(BufPool::new());
        let t = TcpTransport::connect(&honest_addr, Duration::from_secs(10), Arc::clone(&pool))
            .expect("honest worker connect");
        run_worker(Box::new(t), pool).expect("honest worker must finish cleanly");
    });
    // The other worker completes the handshake, then drops the
    // connection on its first compute job — mid-epoch, with gradients
    // outstanding.
    {
        let mut t = connect_and_join(&addr);
        let init = t.recv_blob().expect("init frame");
        assert_eq!(d2ft::dist::proto::peek_tag(&init).unwrap(), d2ft::dist::proto::TAG_INIT);
        t.barrier().expect("handshake barrier");
        let _job = t.recv_blob().expect("first compute job");
        // Vanish without a word.
        drop(t);
    }
    let r = result_rx
        .recv_timeout(Duration::from_secs(120))
        .expect("trainer must recover, not hang on the dead worker")
        .expect("the run must complete on the survivor");
    assert_eq!(r.evictions, 1, "the vanished worker must be evicted");
    assert_eq!(r.live_workers, 1, "only the honest worker remains");
    assert!(r.knapsack_resolves >= 1, "eviction must trigger a knapsack re-solve");
    assert_eq!(
        bits(&rs.loss_curve),
        bits(&r.train.loss_curve),
        "recovery must be bitwise invisible in the loss trajectory"
    );
    honest.join().unwrap();
}

#[test]
fn malformed_uplink_frame_is_rejected_descriptively() {
    let addr = free_addr();
    let result_rx = spawn_trainer(addr.clone(), 1, WireCompression::None);
    // The lone worker completes the handshake, then answers its first
    // compute job with garbage instead of a gradient frame.
    {
        let mut t = connect_and_join(&addr);
        let _init = t.recv_blob().expect("init frame");
        t.barrier().expect("handshake barrier");
        let _job = t.recv_blob().expect("first compute job");
        t.send_blob(vec![0xFF; 12]).expect("sending garbage");
        // Keep the socket open long enough for the frame to land; the
        // aggregator must reject the *content*, not rely on a close.
        thread::sleep(Duration::from_millis(200));
    }
    let result = result_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("trainer must reject the frame, not hang");
    let err = format!("{:#}", result.expect_err("run must fail"));
    assert!(
        err.contains("unexpected frame tag"),
        "error must identify the malformed frame, got: {err}"
    );
}

#[test]
fn mismatched_compression_flags_are_rejected_descriptively() {
    // The aggregator runs an int8 wire; the worker answers every
    // dispatched micro-batch with a well-formed Up header whose
    // gradient tail claims the f32/none format (right magic, wrong
    // flags). The codec must refuse the format mismatch descriptively
    // instead of misparsing the payload as quantized slices.
    let addr = free_addr();
    let result_rx = spawn_trainer(addr.clone(), 1, WireCompression::Int8);
    {
        let mut t = connect_and_join(&addr);
        let _init = t.recv_blob().expect("init frame");
        t.barrier().expect("handshake barrier");
        let job = t.recv_blob().expect("first compute frame");
        let (step, jobs) = d2ft::dist::proto::decode_compute(&job).expect("compute frame");
        assert!(!jobs.is_empty(), "the lone worker must own every micro-batch");
        // Answer every micro so the batch barrier completes and the
        // ordered reduce actually decodes the tails.
        for j in &jobs {
            let hdr = d2ft::dist::proto::UpHdr {
                micro: j.micro,
                loss: 1.0,
                n_correct: 0.0,
                ms: 1.0,
                step,
            };
            let mut up = Vec::new();
            d2ft::dist::proto::encode_up_header(&hdr, &mut up);
            up.extend_from_slice(&0x4432_4647u32.to_le_bytes()); // gradient magic
            up.extend_from_slice(&0u32.to_le_bytes()); // flags: f32/none, codec is int8
            up.extend_from_slice(&[0u8; 20]); // micro + fingerprint + elem count
            t.send_blob(up).expect("sending mismatched gradient frame");
        }
        thread::sleep(Duration::from_millis(200));
    }
    let result = result_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("trainer must reject the frame, not hang");
    let err = format!("{:#}", result.expect_err("run must fail"));
    assert!(
        err.contains("wire format mismatch"),
        "error must identify the compression mismatch, got: {err}"
    );
}

#[test]
fn malformed_join_is_rejected_at_the_handshake() {
    let addr = free_addr();
    let result_rx = spawn_trainer(addr.clone(), 1, WireCompression::None);
    // The connecting link opens with garbage instead of a Join frame.
    {
        let pool = Arc::new(BufPool::new());
        let mut t = TcpTransport::connect(&addr, Duration::from_secs(10), pool)
            .expect("worker connect");
        t.send_blob(vec![0xAB; 8]).expect("sending garbage instead of Join");
        thread::sleep(Duration::from_millis(200));
    }
    let result = result_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("trainer must reject the handshake, not hang");
    let err = format!("{:#}", result.expect_err("run must fail"));
    assert!(
        err.contains("expected Join frame"),
        "error must name the handshake failure, got: {err}"
    );
}

#[test]
fn protocol_version_mismatch_is_rejected_descriptively() {
    let addr = free_addr();
    let result_rx = spawn_trainer(addr.clone(), 1, WireCompression::None);
    // A well-formed Join from the future: right frame, wrong version.
    {
        let pool = Arc::new(BufPool::new());
        let mut t = TcpTransport::connect(&addr, Duration::from_secs(10), pool)
            .expect("worker connect");
        let mut join = Vec::new();
        d2ft::dist::proto::encode_join(
            &d2ft::dist::proto::JoinMsg::fresh(d2ft::dist::proto::PROTO_VERSION + 7),
            &mut join,
        );
        t.send_blob(join).expect("sending wrong-version Join");
        thread::sleep(Duration::from_millis(200));
    }
    let result = result_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("trainer must reject the version, not hang");
    let err = format!("{:#}", result.expect_err("run must fail"));
    assert!(
        err.contains("protocol version"),
        "error must name the version mismatch, got: {err}"
    );
}
