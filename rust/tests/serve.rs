//! The multi-tenant service's contracts, end to end:
//!
//! 1. **Bitwise tenant isolation** — two tenants fine-tuning
//!    concurrently through one service (shared replicas, interleaved
//!    rounds, adapter hot-swap between them) produce *bitwise* the same
//!    trained adapter state as each job run alone in its own service.
//!    The replica rebuilds every job's arithmetic from its `JobSpec`
//!    (datasets, batch order, pretrain trajectory, select-once masks)
//!    and the F32 dense codec round-trips state exactly, so co-tenancy
//!    must be invisible in the bits.
//! 2. **Admission + metering** — submissions are validated against the
//!    fleet (model preset, rank >= 1, tenant cap), completed jobs meter
//!    non-zero adapter bytes far below the dense full-state baseline,
//!    and the aggregate report carries per-tenant byte totals.
//! 3. **Transport parity** — the same jobs complete over real loopback
//!    TCP replica links, and the control plane speaks the newline-JSON
//!    protocol `repro job` uses.
#![cfg(feature = "native")]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use d2ft::config::JobSpec;
use d2ft::serve::{serve, ServeConfig};
use d2ft::util::json::Json;

const WAIT: Duration = Duration::from_secs(300);

/// A short two-round job (8-batch quota over 4-batch rounds) so the
/// adapter state round-trips server <-> replica mid-job.
fn job(tenant: &str, seed: u64, rank: usize) -> JobSpec {
    let mut s = JobSpec::default_for(tenant);
    s.seed = seed;
    s.lora_rank = rank;
    s.pretrain_batches = 1;
    s
}

/// Run one job alone in a fresh single-tenant service and return its
/// completed adapter state.
fn solo_state(spec: &JobSpec) -> (Vec<u8>, Vec<u8>) {
    let mut handle = serve(ServeConfig::new()).expect("solo service");
    let id = handle.submit(spec).expect("solo submit");
    let r = handle.wait(id, WAIT).expect("solo job terminates");
    assert_eq!(r.state, "completed", "solo run failed: {}", r.error);
    let state = handle.final_state(id).expect("completed job exports state");
    handle.shutdown();
    state
}

#[test]
fn concurrent_tenants_match_solo_runs_bitwise() {
    let alice = job("alice", 101, 2);
    let bob = job("bob", 202, 4);

    // Both tenants through one service: different seeds, different
    // adapter ranks, interleaved admission rounds on shared replicas.
    let mut handle = serve(ServeConfig::new()).expect("shared service");
    let a = handle.submit(&alice).expect("submit alice");
    let b = handle.submit(&bob).expect("submit bob");
    let ra = handle.wait(a, WAIT).expect("alice terminates");
    let rb = handle.wait(b, WAIT).expect("bob terminates");
    assert_eq!(ra.state, "completed", "alice failed: {}", ra.error);
    assert_eq!(rb.state, "completed", "bob failed: {}", rb.error);
    let state_a = handle.final_state(a).expect("alice state");
    let state_b = handle.final_state(b).expect("bob state");

    // Metering: both jobs ran their full quota across two rounds and
    // shipped only adapter-sized blobs against the dense baseline.
    for r in [&ra, &rb] {
        assert_eq!(r.batches_done, r.batches_quota);
        assert_eq!(r.rounds, 2, "8-batch quota over 4-batch rounds");
        assert_eq!(r.replica_swaps, 2, "one hot-swap per admitted round");
        assert!(r.bytes_up > 0 && r.bytes_down > 0, "adapter bytes must be metered");
        assert!(r.dense_state_bytes > 0);
        assert!(
            r.adapter_savings > 0.5,
            "tenant {}: adapter swap should be far below a dense swap (savings {})",
            r.tenant,
            r.adapter_savings
        );
        assert!(r.step_ms_p50 > 0.0 && r.step_ms_p99 >= r.step_ms_p50);
        assert!(r.test_top1 >= 0.0, "finalized job carries an eval");
        assert!(r.final_train_loss > 0.0 && r.final_train_loss.is_finite());
    }
    // Higher rank => strictly more adapter parameters on the wire.
    assert!(rb.bytes_down > ra.bytes_down, "rank-4 state must outweigh rank-2 state");

    // Aggregate report: per-tenant byte totals, both tenants present.
    let report = handle.report_json();
    let tenants = report.get("tenants").unwrap().as_arr().unwrap();
    assert_eq!(tenants.len(), 2);
    for t in tenants {
        assert!(t.get("bytes_up").unwrap().as_f64().unwrap() > 0.0);
        assert!(t.get("bytes_down").unwrap().as_f64().unwrap() > 0.0);
    }
    handle.shutdown();

    // The isolation pin: co-tenancy is invisible in the bits.
    assert_eq!(state_a, solo_state(&alice), "alice's adapter drifted under co-tenancy");
    assert_eq!(state_b, solo_state(&bob), "bob's adapter drifted under co-tenancy");
}

#[test]
fn submissions_are_validated_and_tenant_cap_enforced() {
    let mut cfg = ServeConfig::new();
    cfg.max_tenants = 1;
    let handle = serve(cfg).expect("service");

    // Wrong model preset for the fleet.
    let mut wrong_model = job("carol", 7, 2);
    wrong_model.model = "small".to_string();
    assert!(handle.submit(&wrong_model).is_err(), "fleet hosts tiny, job asks small");

    // Rank 0 is full fine-tuning — not multiplexable.
    let mut full_ft = job("carol", 7, 2);
    full_ft.lora_rank = 0;
    assert!(handle.submit(&full_ft).is_err(), "rank-0 jobs must be rejected");

    // A rank outside the preset's supported set fails the job at the
    // replica (spec error, not a service crash).
    // First occupy the single tenant slot...
    let mut carol = job("carol", 7, 2);
    carol.batches = 4;
    let id = handle.submit(&carol).expect("carol fits the cap");
    // ...a second distinct tenant bounces off the cap while carol is
    // active (she may finish quickly, so tolerate either outcome only
    // for the *same* tenant re-submitting).
    let dave = job("dave", 8, 2);
    let dave_res = handle.submit(&dave);
    if let Ok(dave_id) = dave_res {
        // Carol already finished; dave legitimately took the slot.
        handle.wait(dave_id, WAIT).expect("dave terminates");
    }
    let r = handle.wait(id, WAIT).expect("carol terminates");
    assert_eq!(r.state, "completed", "carol failed: {}", r.error);
}

#[test]
fn unsupported_rank_fails_the_job_not_the_service() {
    let handle = serve(ServeConfig::new()).expect("service");
    let mut odd = job("erin", 9, 3); // tiny supports ranks {1, 2, 4, 8}
    odd.batches = 4;
    let id = handle.submit(&odd).expect("rank validity is a replica concern");
    let r = handle.wait(id, WAIT).expect("job terminates");
    assert_eq!(r.state, "failed");
    assert!(r.error.contains("rank"), "error names the rank: {}", r.error);

    // The service keeps serving after the failed job.
    let ok = job("erin", 9, 2);
    let id2 = handle.submit(&ok).expect("submit after failure");
    let r2 = handle.wait(id2, WAIT).expect("job terminates");
    assert_eq!(r2.state, "completed", "follow-up failed: {}", r2.error);
}

#[test]
fn tcp_links_and_control_plane_smoke() {
    let mut cfg = ServeConfig::new();
    cfg.tcp = true;
    cfg.control = Some("127.0.0.1:0".to_string());
    let mut handle = serve(cfg).expect("tcp service");
    let addr = handle.control_addr().expect("control plane bound").to_string();

    // Submit over the control socket exactly as `repro job` does: one
    // compact JSON object per line, one reply per line.
    let mut spec = job("frank", 33, 2);
    spec.batches = 4;
    let stream = TcpStream::connect(&addr).expect("connect control plane");
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);
    let req = format!(
        "{}\n",
        d2ft::util::json::obj(vec![
            ("cmd", d2ft::util::json::s("submit")),
            ("spec", spec.to_json()),
        ])
        .to_string_compact()
    );
    writer.write_all(req.as_bytes()).expect("send submit");
    writer.flush().expect("flush");
    let mut line = String::new();
    reader.read_line(&mut line).expect("read reply");
    let doc = Json::parse(&line).expect("reply is JSON");
    assert_eq!(doc.get("ok").unwrap().as_f64().unwrap(), 1.0, "submit rejected: {line}");
    let id = doc.usize_at("job_id").expect("reply carries the job id") as u64;

    // `result` blocks until terminal and returns the job report.
    let req = format!(
        "{}\n",
        d2ft::util::json::obj(vec![
            ("cmd", d2ft::util::json::s("result")),
            ("job_id", d2ft::util::json::num(id as f64)),
        ])
        .to_string_compact()
    );
    writer.write_all(req.as_bytes()).expect("send result");
    writer.flush().expect("flush");
    line.clear();
    reader.read_line(&mut line).expect("read result");
    let doc = Json::parse(&line).expect("result is JSON");
    assert_eq!(doc.get("ok").unwrap().as_f64().unwrap(), 1.0, "result errored: {line}");
    let report = doc.get("report").unwrap();
    assert_eq!(report.str_at("state").unwrap(), "completed");
    assert_eq!(report.str_at("schema").unwrap(), "d2ft-job-report-v4");
    assert!(report.get("bytes_up").unwrap().as_f64().unwrap() > 0.0);
    drop(reader);
    drop(writer);
    handle.shutdown();
}
