//! Execution-engine determinism tests + scheduler-label round-trip.
//!
//! These run without any AOT artifacts: the synthetic workload drives the
//! real bi-level scheduler and the real engine, just not the PJRT
//! numerics. The headline property: at a fixed seed, parallel execution
//! produces **bitwise-identical** losses and metrics to the serial
//! reference path (`--serial`), so turning the engine on can never change
//! an experiment's result.

use d2ft::cluster::{run_synthetic, ExecMode, SyntheticReport, SyntheticRunConfig};
use d2ft::coordinator::SchedulerKind;
use d2ft::schedule::scaler::Lambda;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Every deterministic field of the report, bit-exact.
fn deterministic_fields(r: &SyntheticReport) -> (Vec<u64>, u64, Vec<u64>) {
    (
        bits(&r.loss_curve),
        r.checksum,
        bits(&[
            r.compute_fraction,
            r.workload_variance,
            r.mean_makespan_ms,
            r.mean_device_ms,
            r.mean_utilization,
            r.imbalance,
            r.comm_saved_ms,
        ]),
    )
}

#[test]
fn parallel_matches_serial_bitwise_at_fixed_seed() {
    for k in [3usize, 8, 13] {
        let mut serial_cfg = SyntheticRunConfig::quick(k, ExecMode::Serial);
        serial_cfg.engine.time_scale = 0.0; // accounting only: keep it fast
        serial_cfg.batches = 12;
        let mut per_device_cfg = serial_cfg;
        per_device_cfg.engine.mode = ExecMode::Parallel { workers: 0 };
        let mut pool_cfg = serial_cfg;
        pool_cfg.engine.mode = ExecMode::Parallel { workers: 3 };

        let serial = run_synthetic(&serial_cfg);
        let per_device = run_synthetic(&per_device_cfg);
        let pool = run_synthetic(&pool_cfg);
        assert_eq!(
            deterministic_fields(&serial),
            deterministic_fields(&per_device),
            "one worker per device must match serial bitwise (K={k})"
        );
        assert_eq!(
            deterministic_fields(&serial),
            deterministic_fields(&pool),
            "fixed worker pool must match serial bitwise (K={k})"
        );
    }
}

#[test]
fn different_seeds_produce_different_runs() {
    let mut a_cfg = SyntheticRunConfig::quick(4, ExecMode::Serial);
    a_cfg.engine.time_scale = 0.0;
    a_cfg.batches = 6;
    let mut b_cfg = a_cfg;
    b_cfg.seed = 18;
    b_cfg.engine.seed = 18;
    let a = run_synthetic(&a_cfg);
    let b = run_synthetic(&b_cfg);
    assert_ne!(a.checksum, b.checksum);
    assert_ne!(bits(&a.loss_curve), bits(&b.loss_curve));
}

#[test]
fn balanced_budget_reports_balanced_cluster() {
    // D2FT's exclusive merge emits exact per-device counts, so the
    // engine must observe a perfectly balanced cluster.
    let mut cfg = SyntheticRunConfig::quick(8, ExecMode::Parallel { workers: 0 });
    cfg.engine.time_scale = 0.0;
    cfg.batches = 8;
    let r = run_synthetic(&cfg);
    assert_eq!(r.workload_variance, 0.0);
    assert!(r.imbalance.abs() < 1e-9, "imbalance {}", r.imbalance);
    assert!((r.mean_utilization - 1.0).abs() < 1e-9);
    // Comm overlap hides transfers behind compute.
    assert!(r.comm_saved_ms > 0.0);
}

#[test]
fn parallel_is_faster_than_serial_with_real_work_at_k8() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 4 {
        eprintln!("skipping speedup assertion: only {cores} core(s)");
        return;
    }
    // Full simulation: every device spins for its modeled time, so the
    // serial path costs ~K times the parallel makespan.
    let mut cfg = SyntheticRunConfig::quick(8, ExecMode::Serial);
    cfg.batches = 8;
    let serial = run_synthetic(&cfg);
    cfg.engine.mode = ExecMode::Parallel { workers: 0 };
    let parallel = run_synthetic(&cfg);
    assert!(
        parallel.wall_s < serial.wall_s,
        "parallel {:.4}s not faster than serial {:.4}s",
        parallel.wall_s,
        serial.wall_s
    );
}

#[test]
fn scheduler_kind_parse_round_trips_every_label() {
    let cases: &[(&str, SchedulerKind)] = &[
        ("d2ft", SchedulerKind::D2ft),
        ("D2FT", SchedulerKind::D2ft), // parsing is case-insensitive
        ("d2ft-paper-merge", SchedulerKind::D2ftPaperMerge),
        ("standard", SchedulerKind::Standard),
        ("random", SchedulerKind::Random),
        ("dpruning-m", SchedulerKind::DPruningM),
        ("dpruning-mg", SchedulerKind::DPruningMG),
        ("moe", SchedulerKind::MoeGshard),
        ("moe-gshard", SchedulerKind::MoeGshard),
        ("scaler-max", SchedulerKind::Scaler(Lambda::Max)),
        ("scaler-min", SchedulerKind::Scaler(Lambda::Min)),
        ("scaler-0.1", SchedulerKind::Scaler(Lambda::Const(0.1))),
        ("scaler-0.2", SchedulerKind::Scaler(Lambda::Const(0.2))),
    ];
    for (label, want) in cases {
        let got = SchedulerKind::parse(label).unwrap();
        assert_eq!(got, *want, "label {label:?}");
    }
    assert!(SchedulerKind::parse("").is_err());
    assert!(SchedulerKind::parse("bogus").is_err());
    assert!(SchedulerKind::parse("scaler-2").is_err());
}
