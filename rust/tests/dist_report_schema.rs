//! Golden tests for every `--report-json` artifact shape.
//!
//! All three report families (serial train, dist, per-tenant job) are
//! JSON contracts consumed outside this crate — the chaos CI step greps
//! the dist counters, the serve smoke asserts on job metering bytes,
//! dashboards parse the byte totals — so each key set is pinned here
//! exactly. Changing any shape must be a conscious act: add/remove the
//! key below AND bump [`d2ft::report::SCHEMA_VERSION`] (shared by all
//! three emitters in `src/report.rs`).
#![cfg(feature = "native")]

use d2ft::backend::native::{NativeProvider, NativeSpec};
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::dist::{DistConfig, DistTrainer};
use d2ft::report::{job_report_json, train_report_json, JobReport, SCHEMA_VERSION};
use d2ft::runtime::ModelConfig;
use d2ft::schedule::Budget;
use d2ft::util::json::Json;

/// The pinned dist-report key set, sorted (JSON objects render in
/// BTreeMap order, so this is also the serialization order). v3 added
/// the crash-recovery counters; v4 moved the emitter into the unified
/// `report` module alongside the train and job schemas.
const DIST_KEYS: &[&str] = &[
    "aggregator_restarts",
    "batches",
    "checkpoints_written",
    "compress",
    "epochs",
    "evictions",
    "exchange",
    "final_train_loss",
    "frames_corrupt",
    "grad_bytes_down",
    "grad_bytes_up",
    "joins",
    "knapsack_resolves",
    "live_workers",
    "membership",
    "reassigned_micros",
    "reconnects",
    "resends",
    "ring_bytes",
    "schema",
    "schema_version",
    "socket_bytes_recv",
    "socket_bytes_sent",
    "socket_classes",
    "test_top1",
    "transport",
    "workers",
];

/// The pinned serial train-report key set (`repro train --report-json`
/// without `--dist`), sorted.
const TRAIN_KEYS: &[&str] = &[
    "backend",
    "batches",
    "calib_epochs",
    "calib_scale",
    "calib_scale_full",
    "calib_scale_fwd",
    "comm_fraction",
    "compute_fraction",
    "engine",
    "final_train_loss",
    "imbalance",
    "makespan_drift",
    "makespan_ms",
    "mean_exec_ms",
    "sample_count_variance",
    "scheduler",
    "schema",
    "schema_version",
    "straggler_ms",
    "test_loss",
    "test_top1",
    "utilization",
    "wall_s",
    "workload_variance",
];

/// The pinned per-tenant job-report key set (the serve metering
/// contract), sorted.
const JOB_KEYS: &[&str] = &[
    "adapter_savings",
    "batches_done",
    "batches_quota",
    "bytes_down",
    "bytes_up",
    "dense_state_bytes",
    "error",
    "final_train_loss",
    "job_id",
    "lora_rank",
    "preemptions",
    "priority",
    "replica_swaps",
    "rounds",
    "schema",
    "schema_version",
    "state",
    "step_ms_p50",
    "step_ms_p99",
    "tenant",
    "test_loss",
    "test_top1",
    "wall_ms",
];

fn small_provider() -> NativeProvider {
    let spec = NativeSpec::builder()
        .config(ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        })
        .micro_batch(2)
        .mb_variants(vec![])
        .lora_ranks(vec![2])
        .lora_standard_rank(2)
        .init_seed(0x90CD)
        .threads(1)
        .build()
        .expect("schema spec");
    NativeProvider::new(spec)
}

fn small_cfg() -> TrainerConfig {
    let mut c = TrainerConfig::quick(
        SyntheticKind::Cifar10Like,
        SchedulerKind::D2ft,
        Budget::uniform(5, 3, 1),
    );
    c.train_size = 40;
    c.test_size = 16;
    c.batches = 2;
    c.pretrain_batches = 1;
    c.update = UpdateMode::BatchAccum;
    c
}

/// Round-trip a report through text and return its sorted key list —
/// the golden contract is about the bytes a consumer parses, not the
/// in-memory Json value.
fn keys_of(doc: &Json) -> Vec<String> {
    doc.as_obj().unwrap().keys().cloned().collect()
}

#[test]
fn dist_report_key_set_and_version_are_pinned() {
    let provider = small_provider();
    let mut dt = DistTrainer::new(&provider, DistConfig::new(small_cfg(), 2)).unwrap();
    let report = dt.run().unwrap();

    let text = report.to_json().to_string_pretty();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(
        keys_of(&doc),
        DIST_KEYS,
        "dist report-JSON key set drifted — bump SCHEMA_VERSION and update this golden list"
    );
    assert_eq!(doc.str_at("schema").unwrap(), "d2ft-dist-report-v4");
    assert_eq!(doc.usize_at("schema_version").unwrap(), SCHEMA_VERSION);
    assert_eq!(doc.usize_at("workers").unwrap(), 2);
    assert_eq!(doc.usize_at("live_workers").unwrap(), 2);
    // Spot-check value kinds a consumer depends on.
    doc.get("final_train_loss").unwrap().as_f64().unwrap();
    doc.get("socket_classes").unwrap().as_arr().unwrap();
    doc.get("membership").unwrap().as_arr().unwrap();
    // The recovery counters the chaos CI step greps must exist and be
    // zero on a fault-free run.
    assert_eq!(doc.usize_at("aggregator_restarts").unwrap(), 0);
    assert_eq!(doc.usize_at("reconnects").unwrap(), 0);
    assert_eq!(doc.usize_at("frames_corrupt").unwrap(), 0);
    assert_eq!(doc.usize_at("resends").unwrap(), 0);
}

#[test]
fn train_report_key_set_and_version_are_pinned() {
    let provider = small_provider();
    let mut t = Trainer::new(&provider, small_cfg()).unwrap();
    let report = t.run().unwrap();

    let text = train_report_json(&report).to_string_pretty();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(
        keys_of(&doc),
        TRAIN_KEYS,
        "train report-JSON key set drifted — bump SCHEMA_VERSION and update this golden list"
    );
    assert_eq!(doc.str_at("schema").unwrap(), "d2ft-train-report-v4");
    assert_eq!(doc.usize_at("schema_version").unwrap(), SCHEMA_VERSION);
    assert_eq!(doc.usize_at("batches").unwrap(), 2);
    assert_eq!(doc.str_at("backend").unwrap(), "native");
    doc.get("final_train_loss").unwrap().as_f64().unwrap();
    doc.get("wall_s").unwrap().as_f64().unwrap();
}

#[test]
fn job_report_key_set_and_version_are_pinned() {
    // The job schema is pinned off a literal report: the serve
    // integration tests exercise live values, while this golden cares
    // only about the serialized key set.
    let report = JobReport {
        job_id: 7,
        tenant: "acme".into(),
        state: "completed".into(),
        error: String::new(),
        lora_rank: 2,
        priority: 1,
        batches_quota: 8,
        batches_done: 8,
        rounds: 2,
        preemptions: 0,
        replica_swaps: 2,
        bytes_up: 4096,
        bytes_down: 4096,
        dense_state_bytes: 1 << 20,
        adapter_savings: 0.99,
        step_ms_p50: 1.5,
        step_ms_p99: 3.0,
        final_train_loss: 2.2,
        test_top1: 0.25,
        test_loss: 2.1,
        wall_ms: 120.0,
    };
    let text = job_report_json(&report).to_string_pretty();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(
        keys_of(&doc),
        JOB_KEYS,
        "job report-JSON key set drifted — bump SCHEMA_VERSION and update this golden list"
    );
    assert_eq!(doc.str_at("schema").unwrap(), "d2ft-job-report-v4");
    assert_eq!(doc.usize_at("schema_version").unwrap(), SCHEMA_VERSION);
    assert_eq!(doc.str_at("tenant").unwrap(), "acme");
    assert_eq!(doc.usize_at("bytes_up").unwrap(), 4096);
    doc.get("adapter_savings").unwrap().as_f64().unwrap();
}
