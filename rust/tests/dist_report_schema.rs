//! Golden test for the `--report-json` artifact shape.
//!
//! The dist report JSON is a contract consumed outside this crate (the
//! chaos CI step greps its counters, dashboards parse its byte totals),
//! so its key set is pinned here exactly. Changing the shape must be a
//! conscious act: add/remove the key below AND bump `schema_version` in
//! [`DistReport::to_json`].
#![cfg(feature = "native")]

use d2ft::backend::native::{NativeProvider, NativeSpec};
use d2ft::coordinator::{SchedulerKind, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::dist::{DistConfig, DistTrainer};
use d2ft::runtime::ModelConfig;
use d2ft::schedule::Budget;
use d2ft::util::json::Json;

/// The pinned v3 key set, sorted (JSON objects render in BTreeMap
/// order, so this is also the serialization order). v3 added the
/// crash-recovery counters: `aggregator_restarts`, `frames_corrupt`,
/// `reconnects`, `resends`.
const GOLDEN_KEYS: &[&str] = &[
    "aggregator_restarts",
    "batches",
    "checkpoints_written",
    "compress",
    "epochs",
    "evictions",
    "exchange",
    "final_train_loss",
    "frames_corrupt",
    "grad_bytes_down",
    "grad_bytes_up",
    "joins",
    "knapsack_resolves",
    "live_workers",
    "membership",
    "reassigned_micros",
    "reconnects",
    "resends",
    "ring_bytes",
    "schema",
    "schema_version",
    "socket_bytes_recv",
    "socket_bytes_sent",
    "socket_classes",
    "test_top1",
    "transport",
    "workers",
];

#[test]
fn report_json_key_set_and_version_are_pinned() {
    let provider = NativeProvider::new(NativeSpec {
        config: ModelConfig {
            img_size: 8,
            patch: 4,
            dim: 16,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
            classes: 10,
            lora_rank: 0,
            head_dim: 8,
            tokens: 5,
        },
        micro_batch: 2,
        mb_variants: vec![],
        lora_ranks: vec![2],
        lora_standard_rank: 2,
        init_seed: 0x90CD,
        threads: 1,
    });
    let cfg = TrainerConfig {
        train_size: 40,
        test_size: 16,
        batches: 2,
        pretrain_batches: 1,
        update: UpdateMode::BatchAccum,
        ..TrainerConfig::quick(
            SyntheticKind::Cifar10Like,
            SchedulerKind::D2ft,
            Budget::uniform(5, 3, 1),
        )
    };
    let mut dt = DistTrainer::new(&provider, DistConfig::new(cfg, 2)).unwrap();
    let report = dt.run().unwrap();

    // Round-trip through text: the golden contract is about the bytes
    // a consumer parses, not the in-memory Json value.
    let text = report.to_json().to_string_pretty();
    let doc = Json::parse(&text).unwrap();
    let keys: Vec<&str> = doc.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
    assert_eq!(
        keys, GOLDEN_KEYS,
        "report-JSON key set drifted — bump schema_version and update this golden list"
    );
    assert_eq!(doc.str_at("schema").unwrap(), "d2ft-dist-report-v3");
    assert_eq!(doc.usize_at("schema_version").unwrap(), 3);
    assert_eq!(doc.usize_at("workers").unwrap(), 2);
    assert_eq!(doc.usize_at("live_workers").unwrap(), 2);
    // Spot-check value kinds a consumer depends on.
    doc.get("final_train_loss").unwrap().as_f64().unwrap();
    doc.get("socket_classes").unwrap().as_arr().unwrap();
    doc.get("membership").unwrap().as_arr().unwrap();
    // The recovery counters the chaos CI step greps must exist and be
    // zero on a fault-free run.
    assert_eq!(doc.usize_at("aggregator_restarts").unwrap(), 0);
    assert_eq!(doc.usize_at("reconnects").unwrap(), 0);
    assert_eq!(doc.usize_at("frames_corrupt").unwrap(), 0);
    assert_eq!(doc.usize_at("resends").unwrap(), 0);
}
