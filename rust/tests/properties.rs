//! Cross-module property tests (no artifacts needed): invariants that
//! tie the scheduler, cost model, partition, and workload accounting
//! together under randomized instances. Failures print a replayable
//! `D2FT_PROP_SEED`.

use d2ft::cluster::{CostModel, ExecTimeModel, WorkloadTracker};
use d2ft::partition::Partition;
use d2ft::runtime::ModelConfig;
use d2ft::schedule::bilevel::{BiLevel, MergeMode};
use d2ft::schedule::dpruning::DPruning;
use d2ft::schedule::moe_gshard::MoeGshard;
use d2ft::schedule::random_sched::RandomSched;
use d2ft::schedule::scaler::{Lambda, ScalerSched};
use d2ft::schedule::{Budget, Op, ScheduleTable, Scheduler};
use d2ft::scores::{Metric, ScoreBook, ScoreConfig};
use d2ft::util::proptest::{check, Gen};

fn cfg(depth: usize, heads: usize) -> ModelConfig {
    ModelConfig {
        img_size: 32, patch: 4, dim: heads * 16, depth, heads,
        mlp_ratio: 4, classes: 10, lora_rank: 0, head_dim: 16,
        tokens: 65,
    }
}

fn gen_book(g: &mut Gen, n_subnets: usize, n_micro: usize) -> ScoreBook {
    let mut b = ScoreBook::zeros(n_subnets, n_micro);
    for k in 0..n_subnets {
        let wm = g.f64_in(0.0, 5.0);
        for i in 0..n_micro {
            b.set(Metric::Fisher, k, i, g.f64_in(0.0, 10.0));
            b.set(Metric::GradMag, k, i, g.f64_in(0.0, 4.0));
            b.set(Metric::Taylor, k, i, g.f64_in(0.0, 2.0));
            b.set(Metric::WeightMag, k, i, wm);
        }
    }
    b
}

fn gen_budget(g: &mut Gen) -> Budget {
    let n_micro = g.usize_in(1, 8);
    let n_full = g.usize_in(0, n_micro);
    let n_fwd = g.usize_in(0, n_micro - n_full);
    Budget::uniform(n_micro, n_full, n_fwd)
}

/// Every scheduler, on every instance: the schedule is well-formed and
/// within each device's compute envelope.
#[test]
fn prop_all_schedulers_respect_budget_envelope() {
    check("schedulers-budget-envelope", 60, |g| {
        let depth = g.usize_in(1, 6);
        let heads = *g.pick(&[2usize, 4, 6]);
        let part = Partition::per_head(&cfg(depth, heads));
        let budget = gen_budget(g);
        let book = gen_book(g, part.n_subnets(), budget.n_micro);
        let cost = CostModel::paper();
        let cap = budget.n_full * cost.full_units() + budget.n_fwd * cost.fwd_units();
        let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(BiLevel::new(ScoreConfig::default(), cost)),
            Box::new(BiLevel::new(ScoreConfig::default(), cost).with_merge(MergeMode::PaperMerge)),
            Box::new(ScalerSched::new(Lambda::Max, ScoreConfig::default(), cost)),
            Box::new(ScalerSched::new(Lambda::Const(0.3), ScoreConfig::default(), cost)),
            Box::new(DPruning::magnitude()),
            Box::new(RandomSched::new(g.usize_in(0, 1 << 20) as u64)),
            Box::new(MoeGshard::new(g.usize_in(0, 1 << 20) as u64, heads)),
        ];
        for s in schedulers.iter_mut() {
            let t = s.schedule(&book, &budget);
            if t.n_subnets != part.n_subnets() || t.n_micro != budget.n_micro {
                return Err(format!("{}: wrong table shape", s.name()));
            }
            // knapsack-driven schedulers must fit the per-device envelope
            // (Random is stochastic per cell and exempt by construction;
            // DPruning is budgeted globally, not per device).
            let per_device = matches!(
                s.name(),
                "D2FT (Ours)" | "Scaler"
            );
            if per_device {
                for k in 0..t.n_subnets {
                    let used: usize =
                        (0..t.n_micro).map(|i| cost.compute_units(t.get(k, i))).sum();
                    if used > cap {
                        return Err(format!(
                            "{}: device {k} used {used} > cap {cap}",
                            s.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Exclusive bi-level never assigns both ops to one (subnet, micro).
#[test]
fn prop_bilevel_ops_mutually_exclusive_and_exact() {
    check("bilevel-exclusive", 80, |g| {
        let part = Partition::per_head(&cfg(g.usize_in(1, 4), 2));
        let budget = gen_budget(g);
        let book = gen_book(g, part.n_subnets(), budget.n_micro);
        let mut s = BiLevel::new(ScoreConfig::default(), CostModel::paper());
        let t = s.schedule(&book, &budget);
        for k in 0..t.n_subnets {
            if t.count_row(k, Op::Full) != budget.n_full {
                return Err(format!("row {k}: p_f count"));
            }
            if t.count_row(k, Op::ForwardOnly) != budget.n_fwd {
                return Err(format!("row {k}: p_o count"));
            }
            let total = t.count_row(k, Op::Full)
                + t.count_row(k, Op::ForwardOnly)
                + t.count_row(k, Op::Shortcut);
            if total != budget.n_micro {
                return Err("ops don't partition the micro-batches".into());
            }
        }
        Ok(())
    });
}

/// The bi-level outer level is optimal: no unselected sample has a
/// higher backward score than a selected one (equal weights -> greedy
/// top-k is optimal, and the DP must match it).
#[test]
fn prop_bilevel_outer_picks_top_backward_scores() {
    check("bilevel-topk", 80, |g| {
        let n_micro = g.usize_in(2, 8);
        let n_full = g.usize_in(1, n_micro);
        let scores: Vec<f64> = (0..n_micro).map(|_| g.f64_in(0.0, 100.0)).collect();
        let s = BiLevel::new(ScoreConfig::default(), CostModel::paper());
        let ops = s.schedule_device(&scores, &vec![0.0; n_micro], n_full, 0);
        let mut picked: Vec<f64> = (0..n_micro)
            .filter(|&i| ops[i] == Op::Full)
            .map(|i| scores[i])
            .collect();
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        picked.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let want: f64 = sorted[..n_full].iter().sum();
        let got: f64 = picked.iter().sum();
        if (got - want).abs() > 1e-9 {
            return Err(format!("picked sum {got} != top-k sum {want}"));
        }
        Ok(())
    });
}

/// Workload accounting is schedule-linear: recording a schedule twice
/// doubles units but keeps fractions and variance identical.
#[test]
fn prop_workload_accounting_linear() {
    check("workload-linear", 60, |g| {
        let k = g.usize_in(1, 30);
        let n = g.usize_in(1, 6);
        let mut t = ScheduleTable::all(k, n, Op::Shortcut);
        for dev in 0..k {
            for i in 0..n {
                let op = match g.usize_in(0, 2) {
                    0 => Op::Full,
                    1 => Op::ForwardOnly,
                    _ => Op::Shortcut,
                };
                t.set(dev, i, op);
            }
        }
        let cost = CostModel::paper();
        let mut w1 = WorkloadTracker::new(cost, k);
        w1.record(&t);
        let mut w2 = WorkloadTracker::new(cost, k);
        w2.record(&t);
        w2.record(&t);
        if (w1.total_compute_fraction() - w2.total_compute_fraction()).abs() > 1e-12 {
            return Err("compute fraction not scale-invariant".into());
        }
        if (w1.workload_variance() - w2.workload_variance()).abs() > 1e-12 {
            return Err("variance not scale-invariant".into());
        }
        if (w1.total_comm_fraction() - w2.total_comm_fraction()).abs() > 1e-12 {
            return Err("comm fraction not scale-invariant".into());
        }
        Ok(())
    });
}

/// Compute fraction equals the budget's analytic fraction for any
/// exact-count schedule (the identity the experiments tables rely on).
#[test]
fn prop_exact_schedule_fraction_matches_budget() {
    check("fraction-identity", 60, |g| {
        let part = Partition::per_head(&cfg(g.usize_in(1, 4), 2));
        let budget = gen_budget(g);
        let book = gen_book(g, part.n_subnets(), budget.n_micro);
        let cost = CostModel::paper();
        let mut s = BiLevel::new(ScoreConfig::default(), cost);
        let t = s.schedule(&book, &budget);
        let mut w = WorkloadTracker::new(cost, part.n_subnets());
        w.record(&t);
        let want = budget.compute_fraction(cost.fwd_frac());
        if (w.total_compute_fraction() - want).abs() > 1e-9 {
            return Err(format!(
                "fraction {} != budget {}",
                w.total_compute_fraction(),
                want
            ));
        }
        let want_comm = budget.comm_fraction();
        if (w.total_comm_fraction() - want_comm).abs() > 1e-9 {
            return Err("comm fraction mismatch".into());
        }
        Ok(())
    });
}

/// Makespan dominates mean device time, and both are monotone under
/// adding work to any device.
#[test]
fn prop_exec_time_monotone() {
    check("exec-time-monotone", 60, |g| {
        let k = g.usize_in(1, 20);
        let n = g.usize_in(1, 6);
        let model = ExecTimeModel::paper();
        let mut t = ScheduleTable::all(k, n, Op::Shortcut);
        for dev in 0..k {
            for i in 0..n {
                if g.bool() {
                    t.set(dev, i, if g.bool() { Op::Full } else { Op::ForwardOnly });
                }
            }
        }
        let mk = model.makespan_ms(&t);
        let mean = model.mean_device_time_ms(&t);
        if mk + 1e-12 < mean {
            return Err(format!("makespan {mk} < mean {mean}"));
        }
        // upgrade one idle cell to Full (strictly more work on that
        // device). Note: upgrading p_o -> p_f can legitimately *reduce*
        // modelled time — the paper's Table IV shows batched-execution
        // amortization (t_full(n+1) - t_full(n) can be smaller than
        // t_fwd(n) - t_fwd(n-1)) — so only Shortcut -> Full is a strict
        // work addition.
        let dev = g.usize_in(0, k - 1);
        if let Some(i) = (0..n).find(|&i| t.get(dev, i) == Op::Shortcut) {
            let before = model.device_time_ms(&t, dev);
            t.set(dev, i, Op::Full);
            let after = model.device_time_ms(&t, dev);
            if after + 1e-12 < before {
                return Err("device time decreased after adding work".into());
            }
            if model.makespan_ms(&t) + 1e-12 < mk {
                return Err("makespan decreased after adding work".into());
            }
        }
        Ok(())
    });
}

/// Masks are consistent with the table across random partitions: a
/// head's fwd/bwd bits equal its owning subnet's op encoding.
#[test]
fn prop_masks_match_table_ops() {
    check("masks-match-ops", 60, |g| {
        let depth = g.usize_in(1, 6);
        let heads = *g.pick(&[2usize, 4, 6]);
        let c = cfg(depth, heads);
        let divisors: Vec<usize> = (1..=heads).filter(|d| heads % d == 0).collect();
        let part = Partition::grouped(&c, *g.pick(&divisors));
        let n_micro = g.usize_in(1, 5);
        let mut t = ScheduleTable::all(part.n_subnets(), n_micro, Op::Shortcut);
        for k in 0..part.n_subnets() {
            for i in 0..n_micro {
                let op = match g.usize_in(0, 2) {
                    0 => Op::Full,
                    1 => Op::ForwardOnly,
                    _ => Op::Shortcut,
                };
                t.set(k, i, op);
            }
        }
        for i in 0..n_micro {
            let m = t.masks_for_micro(&part, i);
            for (k, s) in part.subnets.iter().enumerate() {
                let (want_f, want_b) = match t.get(k, i) {
                    Op::Full => (1.0, 1.0),
                    Op::ForwardOnly => (1.0, 0.0),
                    Op::Shortcut => (0.0, 0.0),
                };
                for h in s.heads() {
                    if m.fwd.at(&[s.block, h]) != want_f || m.bwd.at(&[s.block, h]) != want_b {
                        return Err(format!("mask mismatch at subnet {k} head {h}"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// MoE GShard never exceeds expert capacity and never emits p_o.
#[test]
fn prop_moe_capacity_and_ops() {
    check("moe-capacity", 40, |g| {
        let heads = *g.pick(&[2usize, 4, 6]);
        let depth = g.usize_in(1, 6);
        let part = Partition::per_head(&cfg(depth, heads));
        let budget = gen_budget(g);
        if budget.n_full == 0 {
            return Ok(());
        }
        let book = gen_book(g, part.n_subnets(), budget.n_micro);
        let mut m = MoeGshard::new(g.usize_in(0, 1 << 20) as u64, heads);
        let t = m.schedule(&book, &budget);
        for k in 0..t.n_subnets {
            if t.count_row(k, Op::ForwardOnly) != 0 {
                return Err("gshard emitted p_o".into());
            }
            if t.count_row(k, Op::Full) > budget.n_full.max(1) {
                return Err(format!("expert {k} over capacity"));
            }
        }
        Ok(())
    });
}
